"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), swept over
shapes and dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.tree_conv import tree_conv
from repro.kernels import ops
from repro.kernels.ref import (flash_attention_ref, mamba_scan_ref,
                               tree_conv_ref)


@pytest.mark.parametrize("BH,BKV,Sq,Sk,hd,window,cap", [
    (4, 4, 128, 128, 64, 0, 0.0),
    (8, 2, 256, 256, 64, 0, 0.0),       # GQA 4:1
    (4, 4, 100, 100, 32, 0, 0.0),       # unaligned seq
    (2, 2, 1, 300, 64, 0, 0.0),         # decode: 1 query vs cache
    (4, 2, 256, 256, 64, 128, 0.0),     # sliding window
    (4, 4, 128, 128, 64, 0, 50.0),      # gemma softcap
    (4, 4, 64, 192, 64, 0, 0.0),        # suffix queries (Sq < Sk)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(BH, BKV, Sq, Sk, hd, window, cap, dtype):
    rng = np.random.default_rng(hash((BH, Sq, Sk, hd, window)) % 2**31)
    q = jnp.asarray(rng.standard_normal((BH, Sq, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((BKV, Sk, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((BKV, Sk, hd)), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, softcap=cap,
                          interpret=True)
    G = BH // BKV
    kr = jnp.repeat(k, G, axis=0)
    vr = jnp.repeat(v, G, axis=0)
    ref = flash_attention_ref(q, kr, vr, causal=True, window=window,
                              softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,di,N,chunk,bd", [
    (2, 64, 32, 8, 32, 32),
    (1, 100, 64, 16, 32, 32),           # unaligned time
    (2, 256, 96, 16, 128, 32),          # unaligned channels
])
def test_mamba_scan_vs_ref(B, S, di, N, chunk, bd):
    rng = np.random.default_rng(hash((B, S, di)) % 2**31)
    x = jnp.asarray(rng.standard_normal((B, S, di)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, di))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((di, N))), jnp.float32)
    Bs = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cs = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y = mamba_scan(x, dt, A, Bs, Cs, chunk=chunk, block_d=bd, interpret=True)
    yr, _ = mamba_scan_ref(x, dt, A, Bs, Cs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)


def test_mamba_scan_chunk_invariance():
    """Kernel output must not depend on the chunking."""
    rng = np.random.default_rng(9)
    B, S, di, N = 1, 96, 32, 8
    args = [jnp.asarray(rng.standard_normal((B, S, di)), jnp.float32),
            jnp.asarray(np.abs(rng.standard_normal((B, S, di))) * 0.1, jnp.float32),
            jnp.asarray(-np.abs(rng.standard_normal((di, N))), jnp.float32),
            jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32),
            jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)]
    y1 = mamba_scan(*args, chunk=16, block_d=32, interpret=True)
    y2 = mamba_scan(*args, chunk=96, block_d=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


@pytest.mark.parametrize("Bt,N,F,H", [(3, 16, 8, 12), (2, 64, 27, 96),
                                      (1, 64, 30, 64)])
def test_tree_conv_vs_ref(Bt, N, F, H):
    rng = np.random.default_rng(hash((Bt, N, F, H)) % 2**31)
    feat = rng.standard_normal((Bt, N, F)).astype(np.float32)
    feat[:, 0] = 0.0                                   # null slot
    left = rng.integers(0, N, (Bt, N)).astype(np.int32)
    right = rng.integers(0, N, (Bt, N)).astype(np.int32)
    mask = (rng.random((Bt, N)) > 0.3).astype(np.float32)
    mask[:, 0] = 0.0
    wr, wl, wrt = (rng.standard_normal((F, H)).astype(np.float32) * 0.1
                   for _ in range(3))
    b = rng.standard_normal(H).astype(np.float32) * 0.1
    out = tree_conv(jnp.asarray(feat), jnp.asarray(left), jnp.asarray(right),
                    jnp.asarray(mask), jnp.asarray(wr), jnp.asarray(wl),
                    jnp.asarray(wrt), jnp.asarray(b), interpret=True)
    refs = np.stack([np.asarray(tree_conv_ref(
        jnp.asarray(feat[i]), jnp.asarray(left[i]), jnp.asarray(right[i]),
        jnp.asarray(mask[i]), wr, wl, wrt, b)) for i in range(Bt)])
    np.testing.assert_allclose(np.asarray(out), refs, atol=1e-5)


def test_mha_flash_wrapper_matches_model_layout():
    """ops.mha_flash on (B,S,H,hd) GQA layout vs reference."""
    rng = np.random.default_rng(3)
    B, S, H, K, hd = 2, 64, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
    out = ops.mha_flash(q, k, v, causal=True, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * K, S, hd), H // K, axis=0)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * K, S, hd), H // K, axis=0)
    ref = flash_attention_ref(qf, kr, vr, causal=True)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
