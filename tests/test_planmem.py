"""Plan memory + background superoptimization (serve/plans/).

Pins the PR-10 contracts:
  * memory OFF (absent, or attached-but-empty with ingest off) is
    completion-bit-identical to the bare scheduler;
  * a probe hit replays EXACTLY the stored action sequence with zero
    act_batch participation, and the replayed plan actually takes
    effect (latency matches the scripted plan, not the agent's);
  * deltas and re-ANALYZEs FENCE entries (skip probe, survive as
    priors) instead of deleting them, and a fenced template falls back
    to the agent;
  * the superoptimizer is run-to-run deterministic and never promotes
    a candidate that fails or loses to the re-simulated incumbent;
  * checkpoint save/load restores entries bit-identically;
  * the QoS ladder's memo rung admits only on a memory hit;
  * the harvester skips memoized completions and feeds observed
    latencies back into entry stats;
  * the RCA engine attributes regressions to stale memos only when
    fence events are present.
"""
import numpy as np
import pytest

from scenarios import (barrier_stream, fast_query, fresh_db, mi_join_query,
                       noop_agent_for, trap_query)

from repro.serve.plans import (PlanEntry, PlanMemory, Superoptimizer,
                               band_for, template_signature)
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.sql.cbo import Estimator
from repro.sql.query import Query


def _sched(db, agent, **kw):
    return LaneScheduler(db, Estimator(db, db.stats), agent, **kw)


def _sig(comps):
    return [(c.seq, c.admit_t, c.finish_t, tuple(c.traj.actions),
             c.result.latency, c.result.failed) for c in comps]


def _mixed_stream():
    qs = [trap_query(0, 1980), fast_query(0), mi_join_query()]
    out = [Arrival(0.1 * i, query=qs[i % 3], seed=i) for i in range(9)]
    return qs, out


# ----------------------------------------------------------- keying
def test_template_signature_is_structural_not_named():
    a, b = trap_query(0, 1980), trap_query(1, 1980)
    assert a.name != b.name
    assert template_signature(a) == template_signature(b)
    assert template_signature(a) != template_signature(trap_query(0, 1985))


def test_band_keying_moves_with_versions():
    q = fast_query(0)
    assert band_for(q, {}) == band_for(q, {t: 0 for t, _ in band_for(q, {})})
    v1 = {t: 1 for t, _ in band_for(q, {})}
    assert band_for(q, v1) != band_for(q, {})
    # band_width coarsens: version 0 and 1 share a band at width 2
    assert band_for(q, v1, band_width=2) == band_for(q, {}, band_width=2)


# ------------------------------------------------- off => bit-identical
def test_memory_off_is_completion_bit_identical():
    qs, stream = _mixed_stream()
    agent = noop_agent_for(*qs, max_steps=2)

    bare = _sched(fresh_db(), agent, n_lanes=2)
    plain = bare.run(stream)

    mem = PlanMemory(ingest_serving=False)
    withmem = _sched(fresh_db(), agent, n_lanes=2, plan_memory=mem)
    memo = withmem.run(stream)

    assert _sig(plain) == _sig(memo)
    assert not any(c.memoized for c in memo)
    assert mem.stats()["hits"] == 0
    assert mem.stats()["probes"] == len(stream)
    assert len(mem) == 0


# ------------------------------------------------------------- replay
def test_hit_replays_exact_stored_sequence_without_act_batch():
    q = trap_query(0, 1980)
    agent = noop_agent_for(q, max_steps=2)

    # noop baseline: what the agent would have served
    base = _sched(fresh_db(), agent, n_lanes=1)
    base_comps = base.run([Arrival(0.0, query=q, seed=1)])
    assert sum(base.decide_sizes) > 0

    db = fresh_db()
    mem = PlanMemory(ingest_serving=False)
    e = mem.install(q, db.versions, (0,), cost=0.5, source="superopt")
    sched = _sched(db, agent, n_lanes=1, plan_memory=mem)
    comps = sched.run([Arrival(0.0, query=q, seed=1)])

    c = comps[0]
    assert c.memoized
    # the STORED action, not the agent's noop
    assert tuple(c.traj.actions) == (0,)
    assert c.traj.actions != base_comps[0].traj.actions
    # zero policy participation, and the replayed plan took effect
    assert sum(sched.decide_sizes) == 0
    assert c.result.latency < base_comps[0].result.latency
    # stats folded back into the entry
    assert mem.n_hits == 1 and e.n_hits == 1
    assert e.best <= 0.5 and e.n_obs == 2      # install cost + replay


def test_serving_ingest_memoizes_repeats_and_skips_act_batch():
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    mem = PlanMemory()
    sched = _sched(fresh_db(), agent, n_lanes=1, plan_memory=mem)
    comps = sched.run([Arrival(0.2 * i, query=q, seed=i)
                       for i in range(4)])
    assert [c.memoized for c in comps] == [False, True, True, True]
    # every memoized completion replayed the first completion's sequence
    first = tuple(comps[0].traj.actions)
    assert all(tuple(c.traj.actions) == first for c in comps[1:])
    assert mem.stats()["hits"] == 3
    assert sum(sched.decide_sizes) == len(comps[0].traj.actions)


# ------------------------------------------------------------ fencing
def test_delta_fences_entry_and_falls_back_to_agent():
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    mem = PlanMemory()
    sched = _sched(fresh_db(), agent, n_lanes=1, plan_memory=mem)
    comps = sched.run(barrier_stream(q, "movie_info", n_pre=3, n_post=2))

    pre, post = comps[:3], comps[3:]
    assert [c.memoized for c in pre] == [False, True, True]
    # the delta fenced the pre-drift entry: first post-delta arrival
    # misses (its band moved AND the old band's entry is fenced)
    assert post[0].memoized is False
    assert mem.stats()["fenced"] >= 1
    fenced = [e for e in mem.entries() if e.fenced]
    assert fenced and fenced[0].fence_reason == "delta"
    # fenced entries skip probe but survive as priors
    assert mem.prior(q, {"movie_info": 0, "title": 0,
                         "movie_keyword": 0}) is not None
    # ...and serving re-memoizes on the new band
    assert post[1].memoized is True


def test_stats_refresh_fences_matching_tables_only():
    db = fresh_db()
    mem = PlanMemory()
    mem.install(mi_join_query(), db.versions, (0,), cost=1.0)
    mem.install(fast_query(0), db.versions, (0,), cost=1.0)
    n = mem.note_stats_refresh(["movie_info"])
    assert n == 1                          # fast_query has no movie_info
    fenced = [e for e in mem.entries() if e.fenced]
    assert len(fenced) == 1
    assert fenced[0].fence_reason == "re-analyze"
    assert any(t == "movie_info" for t, _ in fenced[0].band)
    assert mem.would_hit(fast_query(0), db.versions)
    assert not mem.would_hit(mi_join_query(), db.versions)


def test_drift_controller_refresh_fences_memory():
    from repro.serve.drift import DriftController, RefreshPolicy
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    db = fresh_db()
    mem = PlanMemory()
    ctl = DriftController(policy=RefreshPolicy("threshold", threshold=0.0),
                          plan_memory=mem)
    sched = _sched(db, agent, n_lanes=1, plan_memory=mem)
    ctl.attach(sched)
    sched.run(barrier_stream(q, "movie_info", n_pre=2, n_post=2))
    assert ctl.stats.tables_refreshed >= 1
    reasons = {e.fence_reason for e in mem.entries() if e.fenced}
    assert "re-analyze" in reasons or "delta" in reasons
    assert mem.stats()["fenced"] >= 1


# ------------------------------------------------------- superoptimizer
def _superopt_pass():
    qs = [trap_query(i % 2, 1980) for i in range(10)]
    agent = noop_agent_for(*qs, max_steps=2)
    mem = PlanMemory()
    so = Superoptimizer(mem, opt_every=4, sim_budget=16)
    sched = _sched(fresh_db(), agent, n_lanes=2, plan_memory=mem)
    so.attach(sched)
    comps = sched.run([Arrival(0.2 * i, query=q, seed=i)
                       for i, q in enumerate(qs)])
    return mem, so, comps


def test_superoptimizer_promotes_deterministically():
    mem1, so1, comps1 = _superopt_pass()
    mem2, so2, comps2 = _superopt_pass()
    assert so1.promote_log == so2.promote_log
    assert _sig(comps1) == _sig(comps2)
    assert so1.stats.promotions >= 1
    for p in so1.promote_log:
        # every promotion strictly beat its re-simulated incumbent
        assert p["incumbent_cost"] is None or \
            p["cost"] < p["incumbent_cost"]
    # the promoted sequence serves subsequent arrivals
    assert any(c.memoized and tuple(c.traj.actions) ==
               tuple(so1.promote_log[0]["actions"]) for c in comps1)
    assert mem1.stats()["promoted_superopt"] == so1.stats.promotions


def test_superoptimizer_never_regresses_incumbent():
    q = trap_query(0, 1980)
    agent = noop_agent_for(q, max_steps=2)
    db = fresh_db()
    mem = PlanMemory(ingest_serving=False)
    # plant the known-best plan as the incumbent
    best = mem.install(q, db.versions, (0,), cost=0.529, source="superopt")
    so = Superoptimizer(mem, opt_every=2, sim_budget=16)
    sched = _sched(db, agent, n_lanes=1, plan_memory=mem)
    so.attach(sched)
    sched.run([Arrival(0.2 * i, query=q, seed=i) for i in range(4)])
    assert so.stats.rounds >= 1
    # nothing beat the incumbent: it must still be installed, unfenced
    assert mem.prior(q, db.versions) is best
    assert not best.fenced


def test_superoptimizer_reads_heat_from_plan_ledger():
    from repro.serve.obs.monitor import PlanLedger
    q = trap_query(0, 1980)
    db = fresh_db()
    mem = PlanMemory()
    ledger = PlanLedger()
    sig = template_signature(q)
    band = band_for(q, db.versions)
    for _ in range(5):
        ledger.observe(0, q.name, band, 0.6, False)
    so = Superoptimizer(mem, ledger=ledger)
    so._heat[(sig, band)] = 1
    so._repr[(sig, band)] = q
    assert so._heat_of((sig, band)) == 5   # ledger counts win
    so.ledger = None
    assert so._heat_of((sig, band)) == 1   # local fallback


# ---------------------------------------------------------- persistence
def test_checkpoint_round_trip_is_bit_identical(tmp_path):
    db = fresh_db()
    mem = PlanMemory(band_width=2)
    e = mem.install(mi_join_query(), db.versions, (0, 3), cost=0.123456789,
                    decoded=("('cbo', 1)", "('lead', 0)"), t=4.2)
    e.observe(0.777)
    mem.install(fast_query(1), db.versions, (), cost=2.5, source="serve")
    mem.fence_table("movie_info", "delta", t=5.0)

    step = mem.save(tmp_path)
    back = PlanMemory.load(tmp_path, step)
    assert back.to_dict() == mem.to_dict()   # floats exact via JSON
    # and the restored memory serves: same key -> same entry actions
    got = back.prior(fast_query(1), db.versions)
    assert got is not None and got.actions == ()

    # a second save goes to a new step; load(None) takes the latest
    mem.install(fast_query(2), db.versions, (1,), cost=0.5)
    step2 = mem.save(tmp_path)
    assert step2 != step
    assert len(PlanMemory.load(tmp_path)) == len(mem)


# ------------------------------------------------------------ QoS rung
def test_ladder_memo_rung_gates_on_memory_hit():
    from repro.serve.qos.degrade import DegradationLadder, _as_budget
    lad = DegradationLadder.with_memo_rung()
    # inside the classic rungs the memo bit changes nothing
    assert lad.choose(1.0, 2.0, memo_hit=True).hook_budget is None
    # severity in (4, 8]: memo hit -> replay rung; miss -> cheapest budget
    hit = lad.choose(6.0, 1.0, memo_hit=True)
    assert (hit.action, hit.hook_budget, hit.memo_only) == ("admit", 0, True)
    miss = lad.choose(6.0, 1.0, memo_hit=False)
    assert (miss.action, miss.hook_budget, miss.memo_only) == \
        ("admit", 0, False)
    assert miss.degraded
    # past reject_above both reject
    assert lad.choose(9.0, 1.0, memo_hit=True).action == "reject"
    assert _as_budget("memo") == 0 and _as_budget(None) is None
    assert _as_budget(2) == 2


def test_qos_admission_counts_memo_admits():
    from scenarios import FixedPredictor
    from repro.serve.qos import QoSAdmission, TenantRegistry
    from repro.serve.qos.degrade import DegradationLadder
    q = fast_query(0)
    agent = noop_agent_for(q, max_steps=2)
    db = fresh_db()
    mem = PlanMemory(ingest_serving=False)
    mem.install(q, db.versions, (), cost=0.25)
    adm = QoSAdmission(TenantRegistry(), predictor=FixedPredictor(),
                       ladder=DegradationLadder.with_memo_rung(),
                       plan_memory=mem)
    sched = _sched(db, agent, n_lanes=1, admission=adm, plan_memory=mem)
    # predicted 1s, deadline slack ~0.2s => severity ~5: memo rung
    comps = sched.run([Arrival(0.0, query=q, seed=1, deadline=0.2)])
    assert len(comps) == 1 and comps[0].memoized
    assert adm.n_memo_admits == 1
    assert adm.stats()["memo_admits"] == 1


# ----------------------------------------------------- harvester seam
def test_harvester_skips_memoized_and_feeds_back_latency():
    from repro.learn import TrajectoryHarvester
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    db = fresh_db()
    mem = PlanMemory(ingest_serving=False)
    e = mem.install(q, db.versions, (10,), cost=1.0)
    n_obs0, best0 = e.n_obs, e.best
    harv = TrajectoryHarvester(plan_memory=mem)
    sched = _sched(db, agent, n_lanes=1, plan_memory=mem)
    harv.attach(sched)
    comps = sched.run([Arrival(0.2 * i, query=q, seed=i)
                       for i in range(3)])
    assert all(c.memoized for c in comps)
    assert harv.n_memoized == 3 and harv.n_harvested == 0
    assert len(harv.replay) == 0

    # a non-memoized completion feeds its latency into the entry stats
    mem2 = PlanMemory(ingest_serving=False)
    e2 = mem2.install(q, db.versions, (10,), cost=1.0)
    e2.fenced = True                      # probe misses, entry remains
    harv2 = TrajectoryHarvester(plan_memory=mem2)
    sched2 = _sched(fresh_db(), agent, n_lanes=1, plan_memory=mem2)
    harv2.attach(sched2)
    comps2 = sched2.run([Arrival(0.0, query=q, seed=1)])
    assert not comps2[0].memoized
    assert harv2.n_fed_back == 1 and harv2.n_harvested == 1
    assert e2.n_obs == 2
    assert e2.best == 1.0                 # feedback never moves best


# ------------------------------------------------------------- obs/RCA
def test_obs_events_and_stale_memo_attribution():
    from repro.serve.obs import Tracer
    from repro.serve.obs.rca import CAUSES, attribute
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    mem = PlanMemory()
    tracer = Tracer()
    sched = _sched(fresh_db(), agent, n_lanes=1)
    tracer.attach(sched)
    mem.attach(sched)
    sched.run(barrier_stream(q, "movie_info", n_pre=2, n_post=2))
    kinds = {e.kind for e in tracer.events}
    assert {"plan_memory_miss", "plan_memory_hit", "plan_memory_promoted",
            "plan_memory_fenced"} <= kinds
    assert tracer.metrics.snapshot()["counters"]["events[plan_memory_hit]"] >= 1

    assert "stale_memo" in CAUSES
    fences = [e for e in tracer.events if e.kind == "plan_memory_fenced"]
    win = [{"latency": 3.0, "arrival_t": 1.0, "tenant": "default",
            "template": "q_mi", "band": (("movie_info", 1),), "step": 0,
            "phases": {"queue": 0.1, "execute": 2.9, "retry": 0.0,
                       "hedge": 0.0},
            "failed": False, "failure_kind": "", "fail_kinds": ()}]
    hyps = attribute(tenant="", metric_label="p99", window=win,
                     baseline=[], events=fences)
    assert any(h.cause == "stale_memo" for h in hyps)
    # no fence events => no stale_memo hypothesis (the gate)
    hyps2 = attribute(tenant="", metric_label="p99", window=win,
                      baseline=[], events=[])
    assert not any(h.cause == "stale_memo" for h in hyps2)


# ------------------------------------------------------- service stats
def test_service_reports_plan_memory_stats():
    from repro.serve.service import QueryService
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    mem = PlanMemory()
    svc = QueryService(fresh_db(), agent, n_lanes=2, plan_memory=mem)
    comps, stats = svc.run_queries([q] * 4)
    assert stats.n_memoized == sum(c.memoized for c in comps) > 0
    assert stats.plan_memory == mem.stats()
    assert stats.plan_memory["hits"] > 0
    svc.reset_stats()
    assert mem.stats()["probes"] == 0 and len(mem) > 0
    svc.reset_stats(clear_entries=True)
    assert len(mem) == 0
