"""Engine invariants: exact join correctness vs brute force, Alg. 2 plan
transformations preserve semantics and never create cross joins, AQE
operator switching, OOM/timeout semantics, shuffle accounting.

Property-style tests use seeded sweeps (hypothesis is not installed in this
offline container — see DESIGN.md §Testing note)."""
import numpy as np
import pytest

from repro.sql import datagen, workloads
from repro.sql.catalog import Database, Table, analyze
from repro.sql.cbo import Estimator, cbo_plan, dp_join_order, greedy_join_order
from repro.sql.cluster import ClusterModel
from repro.sql.executor import (Executor, QueryFailure, annotate_methods,
                                run_adaptive, RuntimeState, planned_shuffles)
from repro.sql.plans import (BHJ, SMJ, apply_broadcast, apply_lead,
                             apply_swap, build_left_deep, is_bushy, joins,
                             leaves, syntactic_plan)
from repro.sql.query import Filter, JoinCond, Query, Relation


def _brute_force_count(db, query):
    """Nested-loop join cardinality via pandas-free numpy (small tables)."""
    rels = list(query.relations)
    rows = None
    for r in rels:
        t = db.table(r.table)
        mask = np.ones(t.nrows, bool)
        for f in r.filters:
            mask &= f.apply(t.columns[f.column])
        idx = np.flatnonzero(mask)
        cols = {(r.alias, c): (t.columns[c][idx] if c in t.columns
                               else idx.astype(np.int64))
                for c in set(
                    [x for cond in query.conds for x in
                     ([cond.lcol] if cond.left == r.alias else []) +
                     ([cond.rcol] if cond.right == r.alias else [])] or ["id"])}
        if rows is None:
            rows = cols
            n = len(idx)
            continue
        # cartesian then filter by all applicable conds
        m = len(idx)
        newrows = {k: np.repeat(v, m) for k, v in rows.items()}
        newrows.update({k: np.tile(v, n) for k, v in cols.items()})
        keep = np.ones(n * m, bool)
        done_aliases = {a for (a, _) in rows.keys()} | {r.alias}
        for c in query.conds:
            if c.left in done_aliases and c.right in done_aliases and (
                    (c.left, c.lcol) in newrows and (c.right, c.rcol) in newrows):
                keep &= newrows[(c.left, c.lcol)] == newrows[(c.right, c.rcol)]
        rows = {k: v[keep] for k, v in newrows.items()}
        n = int(keep.sum())
    return n


def _tiny_db(seed=0):
    rng = np.random.default_rng(seed)
    t = {"a": Table("a", {"id": np.arange(30, dtype=np.int64),
                          "x": rng.integers(0, 5, 30).astype(np.int64)}),
         "b": Table("b", {"a_id": rng.integers(0, 30, 60).astype(np.int64),
                          "c_id": rng.integers(0, 10, 60).astype(np.int64)}),
         "c": Table("c", {"id": np.arange(10, dtype=np.int64)}),
         "d": Table("d", {"a_id": rng.integers(0, 30, 40).astype(np.int64)})}
    db = Database("tiny", t)
    db.stats = analyze(db)
    return db


def _tiny_query(with_filter=True):
    f = (Filter("x", "<=", (2,)),) if with_filter else ()
    return Query("q", (Relation("a", "a", f), Relation("b", "b"),
                       Relation("c", "c"), Relation("d", "d")),
                 (JoinCond("a", "id", "b", "a_id"),
                  JoinCond("b", "c_id", "c", "id"),
                  JoinCond("a", "id", "d", "a_id")))


@pytest.mark.parametrize("seed", range(6))
def test_join_cardinality_matches_brute_force(seed):
    db = _tiny_db(seed)
    q = _tiny_query()
    expected = _brute_force_count(db, q)
    est = Estimator(db, db.stats)
    res = run_adaptive(db, q, syntactic_plan(q), est, ClusterModel())
    assert not res.failed
    assert res.stages[-1].out_rows == expected


@pytest.mark.parametrize("seed", range(8))
def test_plan_transforms_preserve_cardinality(seed):
    """ANY order produced by swap/lead yields the same final cardinality
    (join semantics are order-independent) — the engine's core invariant."""
    db = _tiny_db(seed + 100)
    q = _tiny_query()
    est = Estimator(db, db.stats)
    base = run_adaptive(db, q, syntactic_plan(q), est, ClusterModel())
    rng = np.random.default_rng(seed)
    plan = syntactic_plan(q)
    for _ in range(4):
        n = len(leaves(plan))
        if rng.random() < 0.5:
            i, j = sorted(rng.choice(np.arange(1, n + 1), 2, replace=False))
            new = apply_swap(q, plan, int(i), int(j))
        else:
            new = apply_lead(q, plan, int(rng.integers(2, n + 1)))
        if new is not None:
            plan = new
    res = run_adaptive(db, q, plan, est, ClusterModel())
    assert res.stages[-1].out_rows == base.stages[-1].out_rows


def test_alg2_never_creates_cross_join(job_workload):
    """Every join in every transformed plan must have >= 1 condition."""
    rng = np.random.default_rng(0)
    for q in job_workload.test[:8]:
        plan = syntactic_plan(q)
        for _ in range(6):
            n = len(leaves(plan))
            i = int(rng.integers(2, n + 1))
            new = apply_lead(q, plan, i)
            if new is not None:
                plan = new
            for j in joins(plan):
                assert len(j.conds) >= 1


def test_lead_moves_leaf_to_front(job_workload):
    q = job_workload.test[5]
    plan = syntactic_plan(q)
    lvs = leaves(plan)
    n = len(lvs)
    for i in range(2, n + 1):
        new = apply_lead(q, plan, i)
        if new is not None:
            assert leaves(new)[0].aliases == lvs[i - 1].aliases


def test_swap_is_an_involution_on_feasible_pairs(job_workload):
    q = job_workload.test[3]
    plan = syntactic_plan(q)
    n = len(leaves(plan))
    for i in range(1, n):
        new = apply_swap(q, plan, i, i + 1)
        if new is None:
            continue
        back = apply_swap(q, new, i, i + 1)
        if back is not None:
            assert [l.aliases for l in leaves(back)] == \
                [l.aliases for l in leaves(plan)]


def test_aqe_switches_small_side_to_bhj(job_db, estimator, job_workload):
    """With actual bytes below BJT, the executed method must be BHJ even if
    the planner said SMJ (and vice versa above BJT)."""
    q = job_workload.test[0]
    plan = syntactic_plan(q)
    for j in joins(plan):
        j.method = SMJ
    res = run_adaptive(job_db, q, plan, estimator, ClusterModel())
    cl = ClusterModel()
    for rec in res.stages:
        if rec.method == BHJ:
            return            # at least one promotion happened
    # tiny scale: every stage should have had a small side
    assert any(r.method == BHJ for r in res.stages)


def test_oom_on_exploding_join():
    rng = np.random.default_rng(0)
    n = 4000
    db = Database("boom", {
        "l": Table("l", {"k": np.zeros(n, np.int64)}),
        "r": Table("r", {"k": np.zeros(n, np.int64)})})
    db.stats = analyze(db)
    q = Query("boom", (Relation("l", "l"), Relation("r", "r")),
              (JoinCond("l", "k", "r", "k"),))
    res = run_adaptive(db, q, syntactic_plan(q), Estimator(db, db.stats),
                       ClusterModel(materialize_cap=1_000_000))
    assert res.failed and res.failure_kind == "oom"
    assert res.latency == ClusterModel().timeout


def test_partitioning_reuse_reduces_shuffles(job_db, estimator):
    """Consecutive SMJs on the same key reuse partitioning (1 shuffle, not
    2, for the pre-partitioned side)."""
    q = Query("p", (Relation("at", "aka_title"),
                    Relation("cc", "complete_cast"),
                    Relation("ml", "movie_link")),
              (JoinCond("at", "movie_id", "cc", "movie_id"),
               JoinCond("at", "movie_id", "ml", "movie_id")))
    cl = ClusterModel(bjt=1.0)           # force SMJ everywhere
    res = run_adaptive(job_db, q, syntactic_plan(q), estimator, cl)
    assert not res.failed
    # join1: 2 shuffles; join2: intermediate already partitioned on
    # movie_id -> only cast_info shuffles
    assert [s.shuffles for s in res.stages] == [2, 1]


def test_cbo_beats_worst_syntactic_on_average(job_db, estimator, job_workload):
    wins = ties = 0
    for q in job_workload.test[:10]:
        r0 = run_adaptive(job_db, q, syntactic_plan(q), estimator, ClusterModel())
        p1, _ = cbo_plan(q, estimator)
        r1 = run_adaptive(job_db, q, p1, estimator, ClusterModel())
        if r1.latency <= r0.latency * 1.05:
            wins += 1
    assert wins >= 7, f"CBO should rarely lose badly; wins={wins}/10"


def test_dp_join_order_optimal_on_small_query():
    """DP must match exhaustive search on a 4-relation query (C_out)."""
    db = _tiny_db(3)
    q = _tiny_query()
    est = Estimator(db, db.stats)
    plan, secs, n_sub = dp_join_order(q, est)
    assert plan is not None and n_sub > 0
    assert frozenset(a for l in leaves(plan) for a in l.aliases) == \
        frozenset(r.alias for r in q.relations)


def test_planned_shuffles_decreases_with_broadcast_hint(job_db, estimator,
                                                        job_workload):
    q = job_workload.test[2]
    plan = syntactic_plan(q)
    st = RuntimeState(q, plan, {}, estimator, 0, 0.0, 0)
    before = planned_shuffles(plan, st)
    hinted = apply_broadcast(plan, 1)
    after = planned_shuffles(hinted, st)
    assert after <= before


def test_workloads_connected_and_sized():
    for bench, lo, hi in (("job", 4, 17), ("extjob", 3, 10), ("stack", 4, 12)):
        wl = workloads.make_workload(bench, n_train=16, n_test_per_template=1)
        for q in wl.train + wl.test:
            assert q.is_connected(), q.name
            assert lo <= q.n_relations <= hi, (q.name, q.n_relations)


def test_dynamic_snapshot_filters_years():
    full = datagen.make_job_like(scale=0.1, seed=0)
    old = datagen.make_job_like(scale=0.1, seed=0, year_max=1950)
    assert 0 < old.tables["title"].nrows < 0.6 * full.tables["title"].nrows
    assert old.tables["cast_info"].nrows < full.tables["cast_info"].nrows
