"""Substrate: data pipeline determinism/resharding, checkpoint atomicity +
corruption fallback, AdamW math, schedules, gradient compression, elastic
planner and straggler monitor."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import SyntheticLMPipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compress import compress_grads, quantize_int8, dequantize_int8
from repro.runtime import ElasticPlanner, StragglerMonitor


# ------------------------------------------------------------------ data
def test_pipeline_deterministic():
    mk = lambda: SyntheticLMPipeline(vocab_size=512, seq_len=64,
                                     global_batch=8, seed=3,
                                     n_logical_shards=8)
    a, b = mk(), mk()
    for _ in range(3):
        ba, bb = next(a), next(b)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_pipeline_reshard_partitions_batch():
    """Two half-range pipelines concatenate to the full batch at any step."""
    full = SyntheticLMPipeline(vocab_size=512, seq_len=32, global_batch=8,
                               seed=1, n_logical_shards=8, shard_range=(0, 8))
    lo = full.reshard((0, 4))
    hi = full.reshard((4, 8))
    f = full.batch_at(5)["tokens"]
    np.testing.assert_array_equal(
        np.concatenate([lo.batch_at(5)["tokens"], hi.batch_at(5)["tokens"]]),
        f)


def test_pipeline_resume_from_state():
    p = SyntheticLMPipeline(vocab_size=128, seq_len=16, global_batch=4,
                            seed=0, n_logical_shards=4)
    batches = [next(p) for _ in range(4)]
    q = SyntheticLMPipeline(vocab_size=128, seq_len=16, global_batch=4,
                            seed=0, n_logical_shards=4)
    q.state.step = 2
    np.testing.assert_array_equal(next(q)["tokens"], batches[2]["tokens"])


def test_pipeline_prefetch_matches_sync():
    p = SyntheticLMPipeline(vocab_size=128, seq_len=16, global_batch=4,
                            seed=9, n_logical_shards=4)
    sync = [p.batch_at(i)["tokens"] for i in range(3)]
    p.start_prefetch()
    try:
        for i in range(3):
            np.testing.assert_array_equal(next(p)["tokens"], sync[i])
    finally:
        p.stop_prefetch()


# ------------------------------------------------------------------ ckpt
def test_checkpoint_roundtrip_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=2)
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "opt": {"m": np.ones(3, np.float32)}}
    for s in (10, 20, 30):
        t = jax.tree_util.tree_map(lambda x: x + s, tree)
        ck.save(s, t, extra={"data_step": s})
    assert ck.steps() == [20, 30]
    restored, step, extra = ck.restore(tree)
    assert step == 30 and extra["data_step"] == 30
    np.testing.assert_allclose(restored["w"], tree["w"] + 30)


def test_checkpoint_torn_write_falls_back(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=5)
    tree = {"w": np.ones(4, np.float32)}
    ck.save(1, tree)
    ck.save(2, jax.tree_util.tree_map(lambda x: x * 2, tree))
    # corrupt step 2: flip bytes in the array file
    d = tmp_path / "step_00000002"
    f = next(d.glob("*.npy"))
    raw = bytearray(f.read_bytes())
    raw[-4] ^= 0xFF
    f.write_bytes(bytes(raw))
    restored, step, _ = ck.restore(tree)
    assert step == 1                       # checksum mismatch -> fallback
    np.testing.assert_allclose(restored["w"], tree["w"])


def test_checkpoint_async_commit(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"w": np.zeros(8, np.float32)}
    ck.save(5, tree, blocking=False)
    ck.wait()
    assert ck.steps() == [5]


# ------------------------------------------------------------------ optim
def test_adamw_first_step_is_lr_sized():
    """After bias correction, |Δp| of step 1 ~= lr (Adam property)."""
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.ones(4) * 2.0}
    g = {"w": jnp.asarray([0.5, -0.5, 2.0, -2.0])}
    s = adamw_init(p)
    p2, s2, m = adamw_update(p, g, s, cfg)
    step = np.abs(np.asarray(p2["w"] - p["w"]))
    np.testing.assert_allclose(step, cfg.lr, rtol=1e-3)
    assert int(s2["step"]) == 1


def test_adamw_grad_clipping():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([300.0, 400.0, 0.0])}     # norm 500
    _, _, m = adamw_update(p, g, adamw_init(p), cfg)
    assert float(m["grad_norm"]) == pytest.approx(500.0)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.asarray(0), warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(jnp.asarray(10), warmup=10, total=100)) == pytest.approx(1.0)
    end = float(cosine_schedule(jnp.asarray(100), warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-3)


# ------------------------------------------------------------------ compress
def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the CUMULATIVE compressed gradient converges to
    the cumulative true gradient (bias -> 0)."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    err = None
    acc = np.zeros(64, np.float32)
    for t in range(50):
        dq, err = compress_grads(g_true, err)
        acc += np.asarray(dq["w"])
    np.testing.assert_allclose(acc / 50, np.asarray(g_true["w"]), atol=1e-2)


# ------------------------------------------------------------------ elastic
def test_elastic_rebalance_covers_all_shards():
    pl = ElasticPlanner(n_logical_shards=256)
    for pods in ([0, 1], [0, 1, 2], [1, 3, 5, 7]):
        asg = pl.assign(pods)
        covered = sorted((a.lo, a.hi) for a in asg)
        assert covered[0][0] == 0 and covered[-1][1] == 256
        for (l1, h1), (l2, h2) in zip(covered, covered[1:]):
            assert h1 == l2
    plan = pl.on_membership_change([0, 1, 2], [0, 2])
    assert plan["lost"] == [1] and plan["mesh_pods"] == 2


def test_straggler_monitor_flags_slow_host():
    m = StragglerMonitor(threshold=1.5, patience=3)
    for step in range(10):
        for h in range(4):
            m.report(h, 1.0 if h != 2 else 3.0)
        ev = m.evictions()
    assert ev == [2]


# ------------------------------------------------------------------ e2e
def test_train_driver_loss_decreases(tmp_path):
    from repro.launch.train import train
    _, losses = train("qwen1.5-4b", smoke=True, steps=12, global_batch=2,
                      seq_len=64, ckpt_dir=str(tmp_path), ckpt_every=6,
                      log_every=0)
    assert losses[-1] < losses[0]
    ck = Checkpointer(tmp_path)
    assert 12 in ck.steps()


def test_train_driver_restart_continues(tmp_path):
    from repro.launch.train import train
    train("qwen1.5-4b", smoke=True, steps=6, global_batch=2, seq_len=64,
          ckpt_dir=str(tmp_path), ckpt_every=3, log_every=0)
    _, losses = train("qwen1.5-4b", smoke=True, steps=9, global_batch=2,
                      seq_len=64, ckpt_dir=str(tmp_path), ckpt_every=3,
                      restore=True, log_every=0)
    assert len(losses) == 3               # resumed at 6, ran 6..9


def test_serve_driver_generates():
    from repro.launch.serve import BatchedServer
    from repro.configs import registry
    cfg = registry.reduced(registry.get_config("falcon-mamba-7b"))
    srv = BatchedServer(cfg, max_batch=2)
    prompts = np.random.default_rng(0).integers(2, cfg.vocab_size, (2, 12)).astype(np.int32)
    out, stats = srv.generate(prompts, 5)
    assert out.shape == (2, 5)
    assert stats["decode_s"] > 0
