"""Batched rollout engine invariants: resumable AdaptiveRun == callback
run_adaptive, seeded serial rollout == one lane of the lockstep engine
(actions, rewards AND latencies), exactly one batched policy call per
lockstep step (no per-lane policy_probs), batched PPO update sanity, and
the fused VMEM-resident TreeCNN kernel vs the jnp reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nets
from repro.core.agent import AgentConfig, AqoraAgent
from repro.core.encoding import MAX_NODES, WorkloadMeta
from repro.core.rollout import rollout
from repro.core.train_loop import train_agent
from repro.core.vec_rollout import rollout_batch
from repro.kernels.tree_conv import tree_cnn_fused
from repro.sql.cluster import ClusterModel
from repro.sql.executor import AdaptiveRun, run_adaptive
from repro.sql.plans import syntactic_plan


@pytest.fixture(scope="module")
def agent(job_workload):
    meta = WorkloadMeta.from_workload(job_workload)
    return AqoraAgent(meta, AgentConfig(), seed=0)


# ---------------------------------------------------------- AdaptiveRun
def test_adaptive_run_matches_run_adaptive(job_db, job_workload, estimator):
    for q in job_workload.test[:4]:
        ref = run_adaptive(job_db, q, syntactic_plan(q), estimator)
        run = AdaptiveRun(job_db, q, syntactic_plan(q), estimator,
                          max_hook_steps=3)
        st = run.start()
        steps = 0
        while st is not None:
            steps += 1
            st = run.resume(None)          # noop hook at every boundary
        assert steps <= 3
        res = run.result
        assert res is not None and run.done
        assert res.latency == ref.latency
        assert res.total_shuffles == ref.total_shuffles
        assert [s.out_rows for s in res.stages] == \
            [s.out_rows for s in ref.stages]


def test_adaptive_run_threads_cluster_into_state(job_db, job_workload,
                                                 estimator):
    cl = ClusterModel(bjt=123.0)
    run = AdaptiveRun(job_db, job_workload.test[0],
                      syntactic_plan(job_workload.test[0]), estimator, cl)
    st = run.start()
    assert st is not None and st.cluster is cl
    # planned_shuffles must use the run's cluster, not a fresh default
    assert isinstance(st.planned_shuffles(), int)


# ------------------------------------------------- serial == batched lane
def test_batched_rollout_matches_seeded_serial(job_db, job_workload,
                                               estimator, agent):
    qs = job_workload.test[:4]
    seeds = [101, 202, 303, 404]
    serial = [rollout(job_db, q, estimator, agent, stage=3, explore=True,
                      key=s) for q, s in zip(qs, seeds)]
    batched = rollout_batch(job_db, qs, estimator, agent, stage=3,
                            explore=True, seeds=seeds)
    for s, b in zip(serial, batched):
        assert s.actions == b.actions
        assert s.t_execute == b.t_execute
        assert s.rewards == b.rewards
        assert s.failed == b.failed
        assert len(s.states) == len(b.states)
        np.testing.assert_allclose(s.logps, b.logps, atol=1e-6)


def test_batched_rollout_greedy_matches_serial(job_db, job_workload,
                                               estimator, agent):
    qs = job_workload.test[4:7]
    serial = [rollout(job_db, q, estimator, agent, stage=3, explore=False)
              for q in qs]
    batched = rollout_batch(job_db, qs, estimator, agent, stage=3,
                            explore=False)
    for s, b in zip(serial, batched):
        assert s.actions == b.actions and s.t_execute == b.t_execute


# ------------------------------------------- one policy call per step
def test_vectorized_path_batches_policy_calls(job_db, job_workload,
                                              estimator, agent,
                                              monkeypatch):
    qs = job_workload.test[:4]
    calls = {"batch": 0}

    def no_serial_policy(*a, **k):
        raise AssertionError("per-lane policy_probs in the vectorized path")

    orig = agent.act_batch

    def counting_act_batch(*a, **k):
        calls["batch"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(agent, "policy_probs", no_serial_policy)
    monkeypatch.setattr(agent, "act", no_serial_policy)
    monkeypatch.setattr(agent, "act_batch", counting_act_batch)
    trajs = rollout_batch(job_db, qs, estimator, agent, seeds=[1, 2, 3, 4])
    # exactly one batched call (== one device sync) per lockstep step
    assert calls["batch"] == max(len(t.actions) for t in trajs)
    assert all(1 <= len(t.actions) <= agent.cfg.max_steps for t in trajs)


# ------------------------------------------------------- batched learning
def test_ppo_update_batch_finite_and_stateful(job_db, job_workload,
                                              estimator):
    meta = WorkloadMeta.from_workload(job_workload)
    ag = AqoraAgent(meta, AgentConfig(), seed=3)
    trajs = rollout_batch(job_db, job_workload.test[:4], estimator, ag,
                          seeds=[5, 6, 7, 8])
    before = jax.tree_util.tree_map(lambda x: np.asarray(x), ag.actor)
    m = ag.ppo_update_batch(trajs)
    assert np.isfinite(m["actor_loss"]) and np.isfinite(m["critic_loss"])
    moved = any(
        not np.allclose(b, np.asarray(a)) for b, a in zip(
            jax.tree_util.tree_leaves(before),
            jax.tree_util.tree_leaves(ag.actor)))
    assert moved, "episode-batch update must move the actor params"


def test_train_agent_batched_runs_and_logs(job_db, job_workload, estimator):
    agent, logs = train_agent(job_db, job_workload, episodes=8, seed=0,
                              est=estimator, batch_size=4)
    assert len(logs) == 8
    assert [l.episode for l in logs] == list(range(8))
    assert all(np.isfinite(l.actor_loss) for l in logs)


# ------------------------------------------------------- fused TreeCNN
@pytest.mark.parametrize("B,N,F,H,tile", [(5, 64, 27, 96, 2),
                                          (8, 16, 8, 32, 8),
                                          (3, 32, 12, 48, 4)])
def test_tree_cnn_fused_matches_reference(B, N, F, H, tile):
    rng = np.random.default_rng(hash((B, N, F, H)) % 2 ** 31)
    feat = rng.standard_normal((B, N, F)).astype(np.float32)
    feat[:, 0] = 0.0                                   # null slot
    left = rng.integers(0, N, (B, N)).astype(np.int32)
    right = rng.integers(0, N, (B, N)).astype(np.int32)
    mask = (rng.random((B, N)) > 0.3).astype(np.float32)
    mask[:, 0] = 0.0
    params = nets._init_treecnn(jax.random.PRNGKey(0), F, H)
    out = tree_cnn_fused(jnp.asarray(feat), jnp.asarray(left),
                         jnp.asarray(right), jnp.asarray(mask), params,
                         tile=tile, interpret=True)
    assert out.shape == (B, H)
    ref = np.stack([np.asarray(nets._apply_treecnn(
        params, jnp.asarray(feat[i]), jnp.asarray(left[i]),
        jnp.asarray(right[i]), jnp.asarray(mask[i]))) for i in range(B)])
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)


def test_apply_encoder_batched_dispatch_fused_equals_vmap():
    rng = np.random.default_rng(9)
    B, N, F, H = 4, 32, 10, 24
    feat = jnp.asarray(rng.standard_normal((B, N, F)), jnp.float32)
    left = jnp.asarray(rng.integers(0, N, (B, N)), jnp.int32)
    right = jnp.asarray(rng.integers(0, N, (B, N)), jnp.int32)
    mask = jnp.asarray((rng.random((B, N)) > 0.4), jnp.float32)
    params = nets._init_treecnn(jax.random.PRNGKey(1), F, H)
    vmapped = nets.apply_encoder(params, "treecnn", feat, left, right, mask)
    fused = nets.apply_encoder(params, "treecnn", feat, left, right, mask,
                               fused=True, interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(vmapped),
                               atol=1e-4, rtol=1e-4)


def test_tree_cnn_fused_grads_match_reference():
    """The custom VJP: grads of a loss through the fused kernel equal the
    grads through the unfused vmapped path, for params AND inputs."""
    rng = np.random.default_rng(17)
    B, N, F, H = 4, 32, 10, 24
    feat = jnp.asarray(rng.standard_normal((B, N, F)), jnp.float32)
    left = jnp.asarray(rng.integers(0, N, (B, N)), jnp.int32)
    right = jnp.asarray(rng.integers(0, N, (B, N)), jnp.int32)
    mask = jnp.asarray((rng.random((B, N)) > 0.4), jnp.float32)
    params = nets._init_treecnn(jax.random.PRNGKey(2), F, H)

    def loss_fused(p, f):
        out = tree_cnn_fused(f, left, right, mask, p, interpret=True)
        return jnp.sum(out ** 2)

    def loss_ref(p, f):
        out = jax.vmap(nets._apply_treecnn, in_axes=(None, 0, 0, 0, 0))(
            p, f, left, right, mask)
        return jnp.sum(out ** 2)

    gp_f, gf_f = jax.grad(loss_fused, argnums=(0, 1))(params, feat)
    gp_r, gf_r = jax.grad(loss_ref, argnums=(0, 1))(params, feat)
    np.testing.assert_allclose(np.asarray(gf_f), np.asarray(gf_r),
                               atol=1e-3, rtol=1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(gp_f),
                    jax.tree_util.tree_leaves(gp_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_fused_agent_trains_through_fused_kernel(job_db, job_workload,
                                                 estimator):
    """With the VJP in place, PPO updates run THROUGH the fused kernel
    (cfg.fused_treecnn routes the batched losses to it) and still learn."""
    meta = WorkloadMeta.from_workload(job_workload)
    ag = AqoraAgent(meta, AgentConfig(fused_treecnn=True), seed=5)
    trajs = rollout_batch(job_db, job_workload.test[:3], estimator, ag,
                          seeds=[11, 12, 13])
    before = jax.tree_util.tree_map(lambda x: np.asarray(x), ag.actor)
    m = ag.ppo_update_batch(trajs)
    assert np.isfinite(m["actor_loss"]) and np.isfinite(m["critic_loss"])
    moved = any(
        not np.allclose(b, np.asarray(a)) for b, a in zip(
            jax.tree_util.tree_leaves(before),
            jax.tree_util.tree_leaves(ag.actor)))
    assert moved, "fused-kernel update must move the actor params"


def test_fused_agent_matches_unfused_actions(job_db, job_workload,
                                             estimator):
    """End to end: an agent with the fused encoder on its batched inference
    path takes the same actions as the reference agent."""
    meta = WorkloadMeta.from_workload(job_workload)
    ref = AqoraAgent(meta, AgentConfig(), seed=4)
    fus = AqoraAgent(meta, AgentConfig(fused_treecnn=True), seed=4)
    qs = job_workload.test[:2]
    t_ref = rollout_batch(job_db, qs, estimator, ref, seeds=[9, 10])
    t_fus = rollout_batch(job_db, qs, estimator, fus, seeds=[9, 10])
    for a, b in zip(t_ref, t_fus):
        assert a.actions == b.actions
        np.testing.assert_allclose(a.logps, b.logps, atol=1e-4)
