"""Property-style invariants over randomized seeded workloads.

Two families, both pure functions of their seeds (so failures replay):

  * the scheduler's virtual clock — whatever the mix of arrivals, lane
    counts and interleaved deltas, completions respect causality
    (arrival <= admit <= finish), each lane serializes its queries, and
    every delta is a STRICT write barrier (everything ahead of it in
    stream order finishes before it applies; everything behind admits
    after it and observes the bumped version);

  * `PartitionedStageCache` byte-budget accounting — under random
    put/get/invalidate/refresh traffic every partition's resident bytes
    equal the sum of its entries, never exceed its budget, and
    admitted − evicted == resident; the aggregate counters equal the sum
    over partitions;

  * the tracer's span trees — under the same seeded chaos, every child
    span nests inside its parent's interval, per-lane attempt spans never
    overlap, each query's non-hedge attempt spans count exactly
    `Completion.attempts`, and timestamps are well-ordered everywhere.
"""
from collections import Counter

import numpy as np
import pytest

from scenarios import fast_query, fresh_db, gen_world_setup, make_agent

from repro.serve.cache import PartitionedStageCache
from repro.serve.deltas import DeltaBatch
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.sql.cbo import Estimator


# ------------------------------------------------------ virtual clock
def _random_stream(rng, n_queries: int, n_deltas: int, *, queries=None,
                   delta_tables=("movie_info",)):
    """Strictly increasing, collision-free arrival times (ties between a
    query and a delta would make 'ahead of the barrier' ambiguous).
    `queries=None` keeps the classic fast_query mix over the JOB world;
    a query list (e.g. a generated world's train set) is sampled
    uniformly instead, with deltas cycling `delta_tables`."""
    arrivals = []
    t = 0.0
    d_i = 0
    kinds = ["q"] * n_queries + ["d"] * n_deltas
    rng.shuffle(kinds)
    if kinds[0] == "d":                        # lead with a query
        kinds[kinds.index("q")], kinds[0] = "d", "q"
    for kind in kinds:
        t += 0.05 + float(rng.exponential(0.4))
        if kind == "q":
            q = fast_query(int(rng.integers(6))) if queries is None \
                else queries[int(rng.integers(len(queries)))]
            arrivals.append(Arrival(t, query=q,
                                    seed=int(rng.integers(2 ** 31))))
        else:
            arrivals.append(Arrival(t, delta=DeltaBatch(
                delta_tables[d_i % len(delta_tables)],
                n_append=int(rng.integers(100, 800)),
                seed=int(rng.integers(2 ** 31)))))
            d_i += 1
    return arrivals


def _world_under_test(request, world: str, seed: int):
    """(db, agent, stream kwargs) for one fuzz case: the hand-built JOB
    world with the session agent, or a generator-sampled world with a
    Noop policy over its own encoding meta."""
    if world == "job":
        return (fresh_db(scale=0.05, seed=seed),
                request.getfixturevalue("agent"),
                dict(queries=None, delta_tables=("movie_info",)))
    w, agent, fast, targets = gen_world_setup(seed)
    return w.db, agent, dict(queries=fast, delta_tables=targets)


WORLDS = [("job", 0), ("job", 1), ("job", 2),
          ("gen", 11), ("gen", 12), ("gen", 13)]


@pytest.mark.parametrize("world,seed", WORLDS)
def test_scheduler_virtual_clock_invariants(request, world, seed):
    rng = np.random.default_rng(100 + seed)
    db, agent, stream_kw = _world_under_test(request, world, seed)
    stream = _random_stream(rng, n_queries=10, n_deltas=2, **stream_kw)
    n_lanes = int(rng.integers(1, 5))
    sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                          n_lanes=n_lanes, policy="async",
                          explore=bool(seed % 2))
    comps = sched.run(stream)
    queries = [a for a in stream if a.delta is None]
    deltas = [a for a in stream if a.delta is not None]
    assert len(comps) == len(queries)
    assert len(sched.delta_log) == len(deltas)

    # causality per completion
    by_seq = {}
    for c in comps:
        assert c.finish_t > c.admit_t >= c.arrival_t
        by_seq[c.seq] = c
    assert [c.seq for c in comps] == sorted(by_seq)   # stream order out

    # monotone per-lane serialization: a lane never admits its next query
    # before its previous one finished
    for lane in range(n_lanes):
        mine = sorted((c for c in comps if c.lane == lane),
                      key=lambda c: c.admit_t)
        for prev, nxt in zip(mine, mine[1:]):
            assert nxt.admit_t >= prev.finish_t
        assert [c.finish_t for c in mine] == \
            sorted(c.finish_t for c in mine)

    # deltas are strict write barriers in stream order
    seq_of = {id(a): i for i, a in enumerate(stream)}
    for (t_apply, delta, counts), d_arr in zip(sched.delta_log, deltas):
        assert t_apply >= d_arr.t
        d_pos = seq_of[id(d_arr)]
        ahead = [c for c in comps if c.seq < d_pos]
        behind = [c for c in comps if c.seq > d_pos]
        assert all(c.finish_t <= t_apply for c in ahead)
        assert all(c.admit_t >= t_apply for c in behind)
    # every delta observable: each table's final version == its delta count
    for table, n in Counter(a.delta.table for a in deltas).items():
        assert db.table_version(table) == n


@pytest.mark.parametrize("seed", [3, 4])
def test_scheduler_policies_agree_on_service_times(job_workload, agent,
                                                   seed):
    """Async vs lockstep over the same randomized stream: identical
    per-query plans and service times; only queueing differs (and the
    virtual-clock invariants hold for both)."""
    rng = np.random.default_rng(200 + seed)
    db = fresh_db(scale=0.05, seed=seed)
    stream = _random_stream(rng, n_queries=8, n_deltas=1)
    est = Estimator(db, db.stats)

    def serve(policy):
        db2 = fresh_db(scale=0.05, seed=seed)
        sched = LaneScheduler(db2, Estimator(db2, db2.stats), agent,
                              n_lanes=2, policy=policy)
        return sched.run(stream)

    a, l = serve("async"), serve("lockstep")
    for ca, cl in zip(a, l):
        assert ca.seq == cl.seq
        assert ca.traj.actions == cl.traj.actions
        assert ca.result.latency == cl.result.latency


# ------------------------------------------------- chaos (serve.recover)
@pytest.mark.parametrize("world,seed", [("job", 0), ("job", 1), ("job", 2),
                                        ("gen", 21), ("gen", 22)])
def test_virtual_clock_invariants_survive_fault_schedules(request, world,
                                                          seed):
    """The PR-5 invariants hold under seeded chaos: whatever mix of
    crashes, transients, stragglers, retries and hedges a fault schedule
    produces — over the hand-built JOB world AND generator-sampled
    worlds — completions respect causality, lanes stay serialized,
    deltas remain STRICT write barriers (retries of pre-delta queries
    drain before the delta applies), every query still emits exactly one
    Completion — and the whole storm replays bit-identically."""
    from scenarios import FixedPredictor
    from repro.serve.recover import (FaultInjector, HedgePolicy,
                                     RecoveryManager, RetryPolicy)

    rng = np.random.default_rng(500 + seed)
    _, agent, stream_kw = _world_under_test(request, world, seed)
    stream = _random_stream(rng, n_queries=12, n_deltas=2, **stream_kw)
    n_lanes = int(rng.integers(2, 5))

    def serve():
        if world == "job":
            db = fresh_db(scale=0.05, seed=seed)
        else:
            db = gen_world_setup(seed)[0].db       # fresh materialization
        mgr = RecoveryManager(
            injector=FaultInjector(seed=900 + seed, p_crash=0.05,
                                   p_transient=0.25, p_slow=0.2,
                                   p_corrupt=0.1),
            retry=RetryPolicy(max_attempts=3, backoff=0.2),
            hedge=HedgePolicy(factor=4.0, predictor=FixedPredictor()))
        sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                              n_lanes=n_lanes, recovery=mgr)
        return sched.run(stream), sched, mgr, db

    comps, sched, mgr, db = serve()
    queries = [a for a in stream if a.delta is None]
    deltas = [a for a in stream if a.delta is not None]
    assert len(comps) == len(queries)            # one Completion per query
    assert len(sched.delta_log) == len(deltas)
    assert mgr.stats.n_failures > 0, "chaos at these rates must bite"

    by_seq = {}
    for c in comps:
        assert c.finish_t > c.admit_t >= c.arrival_t
        assert c.admit_t >= c.first_admit_t >= 0.0
        assert c.attempts >= 1
        if c.recovered:
            assert c.attempts > 1 and not c.result.failed
        by_seq[c.seq] = c
    assert [c.seq for c in comps] == sorted(by_seq)   # stream order out

    # per-lane serialization: final-attempt occupancies on one lane never
    # overlap (intermediate attempts ran under the same exclusivity — the
    # scheduler asserts a lane is free before every _start)
    for lane in range(n_lanes):
        mine = sorted((c for c in comps if c.lane == lane),
                      key=lambda c: c.admit_t)
        for prev, nxt in zip(mine, mine[1:]):
            assert nxt.admit_t >= prev.finish_t

    # strict write barriers, retries included: everything ahead of a delta
    # in stream order (plus all its retries) drains before the apply
    seq_of = {id(a): i for i, a in enumerate(stream)}
    for (t_apply, delta, counts), d_arr in zip(sched.delta_log, deltas):
        d_pos = seq_of[id(d_arr)]
        assert all(c.finish_t <= t_apply
                   for c in comps if c.seq < d_pos)
        assert all(c.admit_t >= t_apply
                   for c in comps if c.seq > d_pos)
    for table, n in Counter(a.delta.table for a in deltas).items():
        assert db.table_version(table) == n

    # the same chaos replays bit-identically
    comps2, _, mgr2, _ = serve()
    assert [(c.seq, c.admit_t, c.finish_t, c.lane, c.attempts,
             c.result.failed, c.hedged) for c in comps] == \
        [(c.seq, c.admit_t, c.finish_t, c.lane, c.attempts,
          c.result.failed, c.hedged) for c in comps2]
    assert mgr.stats.as_dict() == mgr2.stats.as_dict()


# --------------------------------------------------- span trees (serve.obs)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_span_tree_invariants_under_chaos(job_workload, agent, seed):
    """Trace a seeded chaos storm and check the span-tree geometry: the
    randomized mix of crashes, retries, hedges and deltas exercises every
    assembly path (cancelled losers, backoffs, clamped timeout stages)."""
    from scenarios import FixedPredictor
    from repro.serve.obs import Tracer
    from repro.serve.recover import (FaultInjector, HedgePolicy,
                                     RecoveryManager, RetryPolicy)

    rng = np.random.default_rng(700 + seed)
    stream = _random_stream(rng, n_queries=12, n_deltas=2)
    n_lanes = int(rng.integers(2, 5))
    db = fresh_db(scale=0.05, seed=seed)
    mgr = RecoveryManager(
        injector=FaultInjector(seed=900 + seed, p_crash=0.05,
                               p_transient=0.25, p_slow=0.2,
                               p_corrupt=0.1),
        retry=RetryPolicy(max_attempts=3, backoff=0.2),
        hedge=HedgePolicy(factor=4.0, predictor=FixedPredictor()))
    tracer = Tracer()
    sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                          n_lanes=n_lanes, recovery=mgr)
    tracer.attach(sched)
    comps = sched.run(stream)
    assert mgr.stats.n_failures > 0, "chaos at these rates must bite"

    spans = tracer.spans
    by_id = {s.span_id: s for s in spans}
    roots = tracer.roots()
    assert len(roots) == len(comps)            # exactly one tree per query

    # well-ordered intervals, and children nest inside their parents
    for s in spans:
        assert s.t1 >= s.t0
        if s.parent_id != -1:
            p = by_id[s.parent_id]
            assert p.t0 <= s.t0 and s.t1 <= p.t1
            assert s.seq == p.seq              # trees never cross queries

    # roots mirror their Completion exactly
    by_seq = {c.seq: c for c in comps}
    for r in roots:
        c = by_seq[r.seq]
        assert (r.t0, r.t1, r.lane) == (c.arrival_t, c.finish_t, c.lane)
        assert r.attrs["failed"] == bool(c.result.failed)
        assert r.attrs["attempts"] == c.attempts

    # attempt spans: per-lane occupancy never overlaps (across ALL
    # queries — lanes serialize attempts, hedges included)
    attempt_spans = [s for s in spans if s.name.startswith("attempt")]
    for lane in range(n_lanes):
        mine = sorted((s for s in attempt_spans if s.lane == lane),
                      key=lambda s: s.t0)
        for prev, nxt in zip(mine, mine[1:]):
            assert nxt.t0 >= prev.t1

    # non-hedge attempt spans count the Completion's attempts; the tracer
    # never had to flag a bookkeeping mismatch
    for c in comps:
        n_real = sum(1 for s in attempt_spans
                     if s.seq == c.seq and not s.attrs["hedge"])
        assert n_real == c.attempts
        # exactly one attempt produced the completion
        finals = [s for s in attempt_spans
                  if s.seq == c.seq and s.cat == "execute"]
        assert len(finals) == 1 and finals[0].lane == c.lane
    assert not any(e.kind == "attempt_mismatch" for e in tracer.events)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_monitor_on_is_completion_bit_identical_under_chaos(job_workload,
                                                            agent, seed):
    """The watchdog only watches: over random seeded chaos worlds, a
    tracer + SloMonitor (alerts unwired) must not move a single
    completion — detectors, RCA and incident bookkeeping all run off the
    observation stream, never into the scheduler."""
    from scenarios import FixedPredictor
    from repro.serve.obs import MonitorConfig, SloMonitor, Tracer
    from repro.serve.recover import (FaultInjector, HedgePolicy,
                                     RecoveryManager, RetryPolicy)

    rng = np.random.default_rng(700 + seed)
    stream = _random_stream(rng, n_queries=14, n_deltas=2)
    n_lanes = int(rng.integers(2, 5))

    def serve(monitored):
        db = fresh_db(scale=0.05, seed=seed)
        mgr = RecoveryManager(
            injector=FaultInjector(seed=900 + seed, p_crash=0.05,
                                   p_transient=0.25, p_slow=0.2,
                                   p_corrupt=0.1),
            retry=RetryPolicy(max_attempts=3, backoff=0.2),
            hedge=HedgePolicy(factor=4.0, predictor=FixedPredictor()))
        sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                              n_lanes=n_lanes, recovery=mgr)
        mon = None
        if monitored:
            Tracer().attach(sched)
            mon = SloMonitor(config=MonitorConfig(window=6, min_warm=3,
                                                  min_n=4, cooldown=3,
                                                  merge_gap=6, lookback=8))
            mon.attach(sched)
        comps = sched.run(stream)
        if mon is not None:
            mon.finalize()
        return comps, mon

    def sig(comps):
        return [(c.seq, c.admit_t, c.finish_t, c.lane, c.attempts,
                 c.hedged, c.result.failed, c.result.latency)
                for c in comps]

    plain, _ = serve(False)
    watched, mon = serve(True)
    assert sig(plain) == sig(watched)
    assert len(mon.records) == len(watched)   # it did watch everything


# ------------------------------------------------------ cache accounting
def _check_partition(c):
    assert c.bytes == sum(nb for _, nb in c._entries.values())
    assert c.bytes <= c.max_bytes
    return c.stats


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_partitioned_cache_byte_budget_accounting(seed):
    rng = np.random.default_rng(300 + seed)
    budgets = {"a": int(rng.integers(200, 600)),
               "b": int(rng.integers(50, 200))}
    cache = PartitionedStageCache(default_bytes=int(rng.integers(100, 400)),
                                  budgets=budgets)
    tenants = ["a", "b", "default", "unbudgeted"]
    for op in range(400):
        tenant = tenants[int(rng.integers(len(tenants)))]
        part = cache.partition(tenant)
        key = "default" if part is cache else tenant
        r = rng.random()
        if r < 0.55:                       # put (sometimes a refresh)
            sig = (key, int(rng.integers(30)))
            nbytes = int(rng.integers(1, 120))
            if not part.put(sig, f"e{op}", nbytes):
                # refusal only ever means "could never fit"
                assert nbytes > part.max_bytes or \
                    nbytes > part.max_entry_bytes
        elif r < 0.9:                      # get
            part.get((key, int(rng.integers(30))))
        else:                              # shared O(1) invalidation
            cache.note_invalidation("movie_info")
        for p in cache.partitions().values():
            _check_partition(p)

    # exact admitted − evicted == resident accounting, on a partition fed
    # only NEW signatures (refreshes of a resident sig are not admissions)
    c = cache.partition("a")
    c.clear(), c.stats.reset()
    n_admit = sum(c.put(("x", i), i, 40) for i in range(50))
    assert n_admit - c.stats.evictions == len(c)
    assert c.bytes == 40 * len(c) <= c.max_bytes

    # aggregate counters == sum over partitions (invalidations shared)
    agg = cache.aggregate_stats()
    per = cache.stats_by_tenant()
    for k in ("hits", "misses", "evictions"):
        assert agg[k] == sum(d[k] for d in per.values())
    # invalidation is O(1) and SHARED: one counter on the base object, no
    # per-partition scan/bump
    assert agg["invalidations"] == cache.stats.invalidations
    assert per["default"]["invalidations"] == agg["invalidations"]
    assert per["a"]["invalidations"] == per["b"]["invalidations"] == 0

    # reset_stats: every partition's counters drop, entries survive
    resident = {t: len(cache.partition(t)) for t in ("a", "b", "default")}
    cache.reset_stats()
    for t, d in cache.stats_by_tenant().items():
        assert d["hits"] == d["misses"] == d["evictions"] == 0
    assert {t: len(cache.partition(t))
            for t in ("a", "b", "default")} == resident


# -------------------------------------------------------- plan memory
@pytest.mark.parametrize("world,seed", WORLDS)
def test_plan_memory_fencing_invariants(request, world, seed):
    """Plan-memory invariants over randomized seeded worlds: with
    serving ingest ON under a random query/delta mix, the probe
    accounting is exact (probes == queries, hits + misses == probes,
    hits == memoized completions), memoized replays carry only scripted
    placeholder logps, and every delta-fenced entry names a
    delta-written table in its band — fenced entries skip the probe but
    survive as priors. With ingest OFF, an attached-but-empty memory is
    completion-bit-identical to no memory at all."""
    from repro.serve.plans import PlanMemory

    def case():
        rng = np.random.default_rng(500 + seed)
        db, agent, stream_kw = _world_under_test(request, world, seed)
        stream = _random_stream(rng, n_queries=12, n_deltas=3,
                                **stream_kw)
        return db, agent, stream, int(rng.integers(1, 5))

    def serve(memory):
        db, agent, stream, n_lanes = case()
        sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                              n_lanes=n_lanes, policy="async",
                              plan_memory=memory)
        return sched.run(stream), stream

    def sig(comps):
        return [(c.seq, c.admit_t, c.finish_t, tuple(c.traj.actions),
                 c.result.failed, c.result.latency) for c in comps]

    # off-switch: attached-but-empty, ingest off => bit-identical
    plain, _ = serve(None)
    mem_off = PlanMemory(ingest_serving=False)
    off, _ = serve(mem_off)
    assert sig(plain) == sig(off)
    assert len(mem_off) == 0
    assert mem_off.stats()["hits"] == 0
    assert mem_off.stats()["probes"] == len(plain)

    # ingest on: exact probe accounting + fence provenance
    mem = PlanMemory()
    comps, stream = serve(mem)
    st = mem.stats()
    assert st["probes"] == len(comps)
    assert st["hits"] + st["misses"] == st["probes"]
    assert st["hits"] == sum(c.memoized for c in comps)
    for c in comps:
        if c.memoized:                    # scripted replay, not policy
            assert all(lp == 0.0 for lp in c.traj.logps)
    written = {a.delta.table for a in stream if a.delta is not None}
    for e in mem.entries():
        if e.fenced and e.fence_reason == "delta":
            assert any(t in written for t, _ in e.band)
    # fencing a written table catches every entry banded over it, and
    # fenced entries survive (fence != delete)
    n_before = len(mem)
    for tbl in sorted(written):
        mem.fence_table(tbl, "delta")
    assert len(mem) == n_before
    assert all(e.fenced or not any(t in written for t, _ in e.band)
               for e in mem.entries())
