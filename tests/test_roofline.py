"""Roofline machinery: the HLO analyzer's trip-count accounting (the reason
it exists — cost_analysis counts scan bodies once), collective-traffic
parsing, and the three-term model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hloanalysis
from repro.launch.roofline import Roofline, collective_traffic_bytes, model_flops


def _body(x, w):
    return jnp.tanh(x @ w), None


def test_cost_analysis_counts_scan_once_and_analyzer_fixes_it():
    D, T = 256, 8
    w = jax.ShapeDtypeStruct((T, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def scanned(x, w):
        return jax.lax.scan(_body, x, w)[0]

    def unrolled(x, w):
        for i in range(T):
            x, _ = _body(x, w[i])
        return x

    cs = jax.jit(scanned).lower(x, w).compile()
    cu = jax.jit(unrolled).lower(x, w).compile()
    flops_s = float(hloanalysis.cost_analysis_dict(cs).get("flops", 0))
    flops_u = float(hloanalysis.cost_analysis_dict(cu).get("flops", 0))
    assert flops_s < flops_u / 2, "XLA cost_analysis DOES scale scans now?"

    hs = hloanalysis.analyze(cs.as_text())
    hu = hloanalysis.analyze(cu.as_text())
    expect = 2 * D ** 3 * T
    assert abs(hs.flops - hu.flops) / hu.flops < 0.05
    assert abs(hs.flops - expect) / expect < 0.05


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    h = hloanalysis.analyze(c.as_text())
    assert abs(h.flops - 2 * 64 * 128 * 32) / (2 * 64 * 128 * 32) < 0.01


def test_collective_parser_ring_multipliers():
    hlo = """
ENTRY %main (p: f32[128]) -> f32[128] {
  %ag = f32[1024]{0} all-gather(%p), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[128]{0} reduce-scatter(%ar), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  ROOT %cp = f32[128]{0} collective-permute(%rs), source_target_pairs={{0,1}}
}
"""
    h = hloanalysis.analyze(hlo)
    assert h.collectives["all-gather"] == pytest.approx(4096 * 7 / 8)
    assert h.collectives["all-reduce"] == pytest.approx(2 * 4096 * 3 / 4)
    assert h.collectives["reduce-scatter"] == pytest.approx(512 * 7)
    assert h.collectives["collective-permute"] == pytest.approx(512)
    # legacy standalone parser agrees on kinds present
    legacy = collective_traffic_bytes(hlo)
    assert legacy["all-gather"] == pytest.approx(4096 * 7 / 8)


def test_dynamic_slice_bytes_not_whole_operand():
    """A scan's per-step weight slice must charge slice bytes, not the full
    stacked array, per iteration."""
    D, T = 128, 16
    w = jax.ShapeDtypeStruct((T, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    c = jax.jit(lambda x, w: jax.lax.scan(_body, x, w)[0]).lower(x, w).compile()
    h = hloanalysis.analyze(c.as_text())
    full_every_step = T * (T * D * D * 4)
    assert h.bytes < full_every_step / 2


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_per_device=197e12, bytes_per_device=819e9 * 2,
                 coll_bytes_per_device=50e9 * 3, chips=256,
                 model_flops=197e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(3.0)
    assert r.bottleneck == "collective"
    assert r.t_bound == pytest.approx(3.0)
    assert r.mfu_bound == pytest.approx(0.5 / 3.0)


def test_model_flops_conventions():
    from repro.configs.base import SHAPES
    from repro.configs import registry
    cfg = registry.get_config("qwen3-8b")
    n = 8e9
    assert model_flops(cfg, SHAPES["train_4k"], n) == pytest.approx(
        6 * n * 4096 * 256)
    assert model_flops(cfg, SHAPES["decode_32k"], n) == pytest.approx(
        2 * n * 128)


def test_dryrun_records_complete_and_ok():
    """The background sweep must have produced all 40 cells x 2 meshes,
    each ok (compiled) or an assignment-sanctioned long_500k skip."""
    import json, pathlib
    from repro.configs import registry as reg
    base = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not base.exists():
        pytest.skip("dry-run sweep has not been executed yet")
    for mesh in ("single", "multi"):
        cells = list((base / mesh).glob("*.json"))
        assert len(cells) == 40, f"{mesh}: {len(cells)} cells"
        for f in cells:
            r = json.loads(f.read_text())
            assert r.get("ok"), (mesh, f.stem, r.get("error"))
            if r.get("skipped"):
                assert "long_500k" in f.stem
