"""The observability plane (serve.obs): off means OFF (bit-identical
completions), traces are deterministic, reset_stats really resets,
exports validate against their own schema, and the explainer's phase
attribution is exact. Span-tree geometry lives in test_invariants.py."""
import json

import numpy as np
import pytest

from scenarios import FixedPredictor, fresh_db, qos_setup, qos_stream

from repro.serve.obs import MetricsRegistry, Tracer
from repro.serve.obs.explain import (PHASES, diff_profiles, phases_for,
                                     run_profile)
from repro.serve.obs.export import (chrome_trace, load_trace_jsonl,
                                    validate_trace_jsonl, write_trace_jsonl)
from repro.serve.recover import (FaultInjector, HedgePolicy, RecoveryManager,
                                 RetryPolicy)
from repro.serve.service import QueryService
from repro.sql.cbo import Estimator


def _chaos_recovery(seed):
    return RecoveryManager(
        injector=FaultInjector(seed=seed, p_crash=0.05, p_transient=0.25,
                               p_slow=0.2, p_corrupt=0.1),
        retry=RetryPolicy(max_attempts=3, backoff=0.2),
        hedge=HedgePolicy(factor=4.0, predictor=FixedPredictor()))


def _chaos_stream(rng, n_queries=10):
    from scenarios import fast_query
    from repro.serve.deltas import DeltaBatch
    from repro.serve.scheduler import Arrival
    t, out = 0.0, []
    for i in range(n_queries):
        t += 0.05 + float(rng.exponential(0.4))
        out.append(Arrival(t, query=fast_query(int(rng.integers(6))),
                           seed=int(rng.integers(2 ** 31)),
                           deadline=t + 20.0))
        if i == n_queries // 2:
            out.append(Arrival(t, delta=DeltaBatch(
                "movie_info", n_append=400, seed=5)))
    return out


def _serve(agent, seed, *, obs=None, n_lanes=3):
    db = fresh_db(scale=0.05, seed=0)
    svc = QueryService(db, agent, est=Estimator(db, db.stats),
                       n_lanes=n_lanes, recovery=_chaos_recovery(900 + seed),
                       obs=obs)
    comps, stats = svc.run(
        _chaos_stream(np.random.default_rng(40 + seed)))
    return comps, stats, svc


def _sig(comps):
    return [(c.seq, c.admit_t, c.finish_t, c.lane, c.attempts,
             c.result.failed, c.hedged) for c in comps]


# -------------------------------------------------------------- off == off
@pytest.mark.parametrize("seed", [0, 1])
def test_obs_off_is_bit_identical(job_workload, agent, seed):
    """The tentpole gate: attaching a Tracer must not move a single
    completion — every emit point short-circuits when obs is None, and
    when it isn't, tracing only OBSERVES (chaos, retries and hedges
    included)."""
    off, _, _ = _serve(agent, seed)
    on, _, _ = _serve(agent, seed, obs=Tracer())
    assert _sig(off) == _sig(on)


def test_traces_are_deterministic(job_workload, agent):
    """Same seeded stream, two tracers: byte-identical span/event dumps
    (everything is virtual-clock; no host time leaks into the trace)."""
    t1, t2 = Tracer(), Tracer()
    _serve(agent, 3, obs=t1)
    _serve(agent, 3, obs=t2)
    assert [s.as_dict() for s in t1.spans] == [s.as_dict() for s in t2.spans]
    assert [e.as_dict() for e in t1.events] == \
        [e.as_dict() for e in t2.events]
    assert t1.metrics.snapshot() == t2.metrics.snapshot()


# ------------------------------------------------------------- reset_stats
def test_reset_stats_clears_tracer_and_metrics(job_workload, agent):
    """`QueryService.reset_stats()` drops the tracer's accumulated state
    (spans, events, metrics, flight recorder) along with the cache
    counters, so a reused service re-measures from zero."""
    tracer = Tracer()
    db = fresh_db(scale=0.05, seed=0)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=3,
                       recovery=_chaos_recovery(901), obs=tracer)
    stream = _chaos_stream(np.random.default_rng(41))
    comps, _ = svc.run(stream)
    n_spans = len(tracer.spans)
    assert n_spans > 0 and tracer.events
    assert tracer.metrics.counter("completions").value == len(comps)

    svc.reset_stats(clear_entries=True)
    assert tracer.spans == [] and tracer.events == []
    assert tracer.now == 0.0
    assert tracer.flight.dumps == [] and not tracer.flight._ring
    snap = tracer.metrics.snapshot()
    assert all(v == 0 for v in snap["counters"].values())
    assert snap["histograms"] == {} and snap["n_samples"] == 0
    assert svc.cache.stats.as_dict()["hits"] == 0

    # the service is reusable: an identical re-run on the unmutated parts
    # rebuilds the same-shaped trace from a clean slate
    comps2, _ = svc.run(stream)
    assert len(tracer.roots()) == len(comps2)
    assert tracer.metrics.counter("completions").value == len(comps2)
    # roots arrive in finish order; one per query either way
    assert sorted(s.seq for s in tracer.roots()) == \
        [c.seq for c in comps2]


# ----------------------------------------------------- stats serialization
def test_service_stats_as_dict_round_trips(job_workload, agent):
    """`ServiceStats.as_dict()` / `TenantStats.as_dict()` are the JSON
    surface every benchmark persists: pin the key names and the fact that
    the whole blob survives json round-tripping unchanged."""
    db = fresh_db(scale=0.05, seed=0)
    reg, adm = qos_setup()
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       policy="edf", tenants=reg, admission=adm)
    _, stats = svc.run(qos_stream(job_workload))

    d = stats.as_dict()
    assert d == json.loads(json.dumps(d))        # JSON-round-trip stable
    assert set(d) >= {
        "n_completed", "n_failed", "makespan", "qps", "latency_mean",
        "latency_p50", "latency_p99", "service_mean", "cache", "ticks",
        "mean_decide_batch", "hook_seconds", "queue_wait_mean",
        "queue_wait_p99", "n_rejected", "n_degraded", "n_slo_miss",
        "slo_miss_rate", "per_tenant", "failure_kinds", "attempts_total",
        "n_retried", "n_recovered", "n_hedged", "n_anomalies",
        "n_incidents"}
    assert set(d["per_tenant"]) == {"gold", "bulk"}
    for td in d["per_tenant"].values():
        assert set(td) >= {
            "n_completed", "n_failed", "n_rejected", "n_degraded",
            "n_slo_miss", "slo_miss_rate", "qps", "latency_p50",
            "latency_p99", "queue_wait_mean", "cache", "failure_kinds",
            "n_recovered", "n_hedged", "n_anomalies", "n_incidents"}
    td = stats.per_tenant["gold"].as_dict()
    assert td == json.loads(json.dumps(td))


# ----------------------------------------------------------------- export
def test_export_round_trip_and_validation(job_workload, agent, tmp_path):
    tracer = Tracer()
    _serve(agent, 5, obs=tracer)
    path = str(tmp_path / "trace.jsonl")
    write_trace_jsonl(tracer, path)
    assert validate_trace_jsonl(path) == []

    # header counts really cross-check the body
    lines = open(path).read().splitlines()
    assert validate_trace_jsonl(path) == []
    with open(path, "w") as f:
        f.write("\n".join(lines[:-1]))           # drop one record
    assert validate_trace_jsonl(path)

    # a corrupted span is caught, not silently accepted
    bad = json.loads(lines[1])
    assert bad["type"] == "span"
    bad["cat"] = "nonsense"
    with open(path, "w") as f:
        f.write("\n".join([lines[0], json.dumps(bad)] + lines[2:]))
    assert any("cat" in e for e in validate_trace_jsonl(path))

    ct = chrome_trace(tracer)
    evs = ct["traceEvents"]
    assert evs and all(e["ph"] in ("X", "i", "M") for e in evs)
    n_x = sum(e["ph"] == "X" for e in evs)
    assert n_x == len(tracer.spans)      # zero-width hooks included
    assert sum(e["ph"] == "i" for e in evs) == len(tracer.events)


def test_load_trace_jsonl_round_trips_bit_exact(job_workload, agent,
                                                tmp_path):
    """`load_trace_jsonl` is write's exact inverse: the writer rounds
    before serializing, so metric sample rows come back == the in-memory
    series (bit-exact floats), and span/event/dump records match their
    as_dict forms modulo JSON normalization."""
    tracer = Tracer()
    _serve(agent, 7, obs=tracer)
    path = str(tmp_path / "trace.jsonl")
    write_trace_jsonl(tracer, path)
    loaded = load_trace_jsonl(path)

    assert loaded["samples"] == tracer.metrics.series   # THE bit-exact claim
    norm = lambda rows: json.loads(json.dumps(rows))

    def strip(d):
        return {k: v for k, v in d.items() if k != "type"}

    assert loaded["spans"] == norm([strip(s.as_dict())
                                    for s in tracer.spans])
    assert loaded["events"] == norm([strip(e.as_dict())
                                     for e in tracer.events])
    assert loaded["dumps"] == norm([strip(d) for d in tracer.flight.dumps])
    h = loaded["header"]
    assert (h["n_spans"], h["n_events"], h["n_samples"], h["n_dumps"]) == \
        (len(loaded["spans"]), len(loaded["events"]),
         len(loaded["samples"]), len(loaded["dumps"]))


# -------------------------------------------------------------- explainer
def test_explainer_attribution_is_exact(job_workload, agent):
    """Phases partition each query's latency EXACTLY, so diffing two runs
    of the same stream (here: 1 lane vs 3 lanes — pure queueing delta)
    attributes the total delta to phases with zero residual."""
    t1, t3 = Tracer(), Tracer()
    c1, _, _ = _serve(agent, 7, obs=t1, n_lanes=1)
    c3, _, _ = _serve(agent, 7, obs=t3, n_lanes=3)

    for tracer, comps in ((t1, c1), (t3, c3)):
        prof = run_profile(tracer)
        assert set(prof) == {c.seq for c in comps}
        for c in comps:
            p = prof[c.seq]
            assert p["total"] == pytest.approx(c.latency, abs=1e-12)
            assert sum(p[ph] for ph in PHASES) == \
                pytest.approx(p["total"], abs=1e-9)
            assert all(p[ph] >= -1e-9 for ph in PHASES)

    diff = diff_profiles(run_profile(t1), run_profile(t3),
                         label_a="1lane", label_b="3lanes", q=99.0)
    assert diff["n_common"] == len(c1)
    assert diff["n_only_a"] == diff["n_only_b"] == 0
    for key in ("mean", "pq"):
        d = diff[key]
        assert sum(d["phases"].values()) == \
            pytest.approx(d["delta"], abs=1e-9)
        assert d["delta"] == pytest.approx(d["b"] - d["a"], abs=1e-12)
    # more lanes can only help: the 3-lane run is no slower on average
    assert diff["mean"]["delta"] <= 1e-9


def test_phases_for_handles_degenerate_trees():
    from repro.serve.obs.trace import Span
    root = Span(1, -1, 0, "q0", "query", 0.0, 10.0)
    assert phases_for(root, []) == \
        {"queue": 10.0, "execute": 0.0, "retry": 0.0, "hedge": 0.0}
    kids = [Span(2, 1, 0, "attempt-1", "execute", 4.0, 10.0),
            Span(3, 1, 0, "attempt-1h", "hedge", 2.0, 6.0),
            Span(4, 1, 0, "backoff-1", "retry", 1.0, 3.0)]
    p = phases_for(root, kids)
    # priority execute > hedge > retry on overlap; queue is the residual
    assert p == {"queue": 1.0, "execute": 6.0, "hedge": 2.0, "retry": 1.0}
    assert sum(p.values()) == root.dur


# ---------------------------------------------------------------- metrics
def test_metrics_registry_sampling_and_reset():
    m = MetricsRegistry(interval=5.0)
    state = {"v": 1.0}
    m.gauge("g", fn=lambda: state["v"])
    m.counter("c").inc(2)
    m.advance(1.0)                      # anchors; no boundary crossed yet
    assert m.series == []
    m.advance(6.0)                      # crosses t=5 -> one row, stamped 5
    state["v"] = 2.0
    m.advance(23.0)                     # crosses 10,15,20 -> ONE row at 20
    assert [r["t"] for r in m.series] == [5.0, 20.0]
    assert m.series[0]["c"] == 2 and m.series[1]["g"] == 2.0

    h = m.histogram("lat", (1.0, 10.0))
    h.observe(1.0)                      # boundary value -> lower bucket
    h.observe(50.0)                     # overflow bucket
    assert h.counts == [1, 0, 1]
    assert h.mean == pytest.approx(25.5)
    assert h.as_dict() == {"bounds": [1.0, 10.0], "counts": [1, 0, 1],
                           "n": 2, "sum": 51.0}

    m.reset()
    assert m.counter("c").value == 0 and m.series == []
    assert m.snapshot()["histograms"] == {}
    m.advance(3.0), m.advance(11.0)     # gauge fns survive the reset
    assert m.series and m.series[-1]["g"] == 2.0
