"""Shared deterministic scenario harness for the serving-stack test suites.

test_serve / test_learn / test_qos / test_drift (and the invariant
property tests) all exercise the same handful of situations: a fresh
mutable JOB-like database, sub-second dimension joins around a
deterministic 300s straggler, delta batches acting as write barriers,
drifting streams whose traps only fail after a growth delta, and
multi-tenant SLO traffic. This module is the single home of those
builders — every one is a pure function of its seed, so scenarios are
bit-reproducible across test files and runs.

Conventions:
  * databases are built FRESH per test (`fresh_db`) whenever deltas /
    re-ANALYZE mutate state — never reuse the session fixture for those;
  * streams are plain `Arrival` lists: the scheduler copies arrivals per
    run, so one stream can replay through many schedulers;
  * the straggler is a triple Zipf fact join whose second join blows the
    materialize cap -> OOM -> charged the full 300s timeout, next to
    sub-second dimension joins (the serving benches' staple mix).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.agent import AgentConfig, AqoraAgent
from repro.core.encoding import WorkloadMeta
from repro.serve.deltas import DeltaBatch
from repro.serve.scheduler import Arrival
from repro.sql import datagen
from repro.sql.query import Filter, JoinCond, Query, Relation

import numpy as np


# ------------------------------------------------------------------ worlds
def fresh_db(scale: float = 0.06, seed: int = 0):
    """A fresh mutable JOB-like database (delta/re-ANALYZE tests mutate —
    never hand these the session-scoped fixture)."""
    return datagen.make_job_like(scale=scale, seed=seed)


def generated_world(seed: int, *, scale: float = 0.05, **kw):
    """A small generated world (`repro.gen.world.sample_world`) sized for
    tests: fresh database per call, so delta-mutating suites can't
    cross-contaminate. Same seed => bit-identical world."""
    from repro.gen.world import sample_world
    kw.setdefault("n_templates", 6)
    kw.setdefault("n_train", 12)
    kw.setdefault("t_min", 3)
    kw.setdefault("t_max", 5)
    kw.setdefault("n_queries", 20)
    return sample_world(seed, scale=scale, **kw)


def gen_world_setup(seed: int):
    """(world, agent, fast queries, delta tables) for fuzzing the
    scheduler invariants over a generated world: a Noop policy (plans
    stay syntactic — no jit cost, no random-init interference), the
    world's smaller train joins, and the schema's delete-safe delta
    targets."""
    from repro.gen.spec import delete_safe_tables
    w = generated_world(seed, with_stream=False)
    agent = NoopServeAgent(w.meta, max_steps=2)
    fast = [q for q in w.workload.train if q.n_relations <= 4] \
        or w.workload.train
    return w, agent, fast, delete_safe_tables(w.spec)


def make_agent(workload, seed: int = 0, **cfg_kw) -> AqoraAgent:
    """The standard serving agent over a workload's encoding meta."""
    return AqoraAgent(WorkloadMeta.from_workload(workload),
                      AgentConfig(**cfg_kw), seed=seed)


class NoopServeAgent:
    """Scripted always-noop policy: plans stay exactly syntactic, so
    failure scenarios are a pure function of data + plan (no random-init
    policy interference). `max_steps` > 1 buys mid-run stage boundaries —
    what the hedging control plane needs to observe an overrun."""

    def __init__(self, meta: WorkloadMeta, max_steps: int = 1):
        from repro.core.actions import ActionSpace
        self.meta = meta
        self.cfg = AgentConfig(max_steps=max_steps)
        self.space = ActionSpace(meta.n_tables_max, self.cfg.families)

    def act_batch(self, feat, left, right, mask, amask, keys, *,
                  explore: bool = False):
        B = amask.shape[0]
        return (np.full(B, self.space.noop_idx, np.int32),
                np.zeros(B, np.float32), keys)

    def act(self, enc, am, *, explore: bool = False):
        a, lp, _ = self.act_batch(None, None, None, None, am[None],
                                  np.zeros((1, 2), np.uint32))
        return int(a[0]), float(lp[0])


def noop_agent_for(*queries, max_steps: int = 1,
                   max_tables: int = 3) -> NoopServeAgent:
    """NoopServeAgent whose encoding meta covers exactly `queries`."""
    from repro.sql.workloads import Workload
    wl = Workload(name="scenario", max_tables=max_tables,
                  train=list(queries), test=[])
    return NoopServeAgent(WorkloadMeta.from_workload(wl),
                          max_steps=max_steps)


def fast_subset(wl) -> List[Query]:
    """Dimension-join-ish templates: the sub-second traffic every
    scenario mixes around its stragglers."""
    return [q for q in wl.train if q.n_relations <= 6] or wl.train


# ----------------------------------------------------------------- queries
def fast_query(i: int) -> Query:
    """Tiny two-table dimension join, distinct per `i` (distinct cache
    signatures: flood/working-set scenarios count on that)."""
    return Query(f"fast{i}",
                 (Relation("t", "title",
                           (Filter("production_year", "<=", (1950 + i,)),)),
                  Relation("kt", "kind_type", ())),
                 (JoinCond("t", "kind_id", "kt", "id"),))


def straggler_query() -> Query:
    """Triple Zipf fact join: the second join's match count blows past the
    materialize cap, so the run fails (OOM) and is charged the full 300s
    timeout — a deterministic straggler next to sub-second joins."""
    return Query("straggler",
                 (Relation("ci", "cast_info", ()),
                  Relation("mi", "movie_info", ()),
                  Relation("mk", "movie_keyword", ())),
                 (JoinCond("ci", "movie_id", "mi", "movie_id"),
                  JoinCond("ci", "movie_id", "mk", "movie_id")))


def trap_query(i: int, year: int) -> Query:
    """Fact-fact-first join (cast_info x movie_info, then a filtered
    title): the syntactic order is safe pre-drift and OOMs once cast_info
    grows — the stale-stats trap of the drifting scenarios."""
    return Query(f"trap_{i}",
                 (Relation("ci", "cast_info", ()),
                  Relation("mi", "movie_info", ()),
                  Relation("t", "title",
                           (Filter("production_year", "<=", (year,)),))),
                 (JoinCond("ci", "movie_id", "mi", "movie_id"),
                  JoinCond("t", "id", "ci", "movie_id")))


def mi_join_query(name: str = "q_mi") -> Query:
    """Three-table join through movie_info: appended movie_info rows join
    with existing titles, so post-delta stage cardinalities provably
    change — the invalidation/write-barrier probe query."""
    return Query(name,
                 (Relation("t", "title",
                           (Filter("production_year", "<=", (1990,)),)),
                  Relation("mi", "movie_info", ()),
                  Relation("it", "info_type", ())),
                 (JoinCond("t", "id", "mi", "movie_id"),
                  JoinCond("mi", "info_type_id", "it", "id")))


# ----------------------------------------------------------------- streams
def straggler_mix_stream(n_fast: int = 6, *, strag_seed: int = 0,
                         spacing: float = 0.0) -> List[Arrival]:
    """One straggler at t=0 followed by `n_fast` fast queries: the
    non-blocking-lanes scenario (async must stream the fast ones through
    the other lane while the straggler burns its own)."""
    return [Arrival(0.0, query=straggler_query(), seed=strag_seed)] + \
        [Arrival(spacing * i, query=fast_query(i), seed=i + 1)
         for i in range(n_fast)]


def barrier_stream(query: Query, table: str = "movie_info", *,
                   n_append: int = 1500, delta_seed: int = 3,
                   n_pre: int = 2, n_post: int = 2) -> List[Arrival]:
    """`n_pre` copies of `query`, one delta on `table`, `n_post` copies:
    the write-barrier ordering scenario (pre finishes before the apply,
    post admits after it and sees the appended rows)."""
    pre = [Arrival(0.0, query=query, seed=i + 1) for i in range(n_pre)]
    delta = [Arrival(0.1, delta=DeltaBatch(table, n_append=n_append,
                                           seed=delta_seed))]
    post = [Arrival(0.2 + 0.1 * i, query=query, seed=n_pre + 2 + i)
            for i in range(n_post)]
    return pre + delta + post


def drifting_delta_stream(queries: Sequence[Query], *, n_queries: int,
                          rate: float = 2.0, seed: int = 17,
                          drift_table: str = "cast_info",
                          drift_at: int = 8, growth_rows: int = 0,
                          churn_table: Optional[str] = None,
                          churn_every: int = 0,
                          churn_rows: int = 0) -> List[Arrival]:
    """The drifting scenario: open-loop Poisson arrivals cycling
    `queries`, one growth delta on `drift_table` after `drift_at`
    queries, then optional churn deltas on `churn_table` every
    `churn_every` queries. Deterministic given `seed`."""
    rng = np.random.default_rng(seed)
    t, out, since_churn = 0.0, [], 0
    for i in range(n_queries):
        t += float(rng.exponential(1.0 / rate))
        out.append(Arrival(t, query=queries[i % len(queries)],
                           seed=int(rng.integers(2 ** 31))))
        if i + 1 == drift_at and growth_rows:
            out.append(Arrival(t, delta=DeltaBatch(
                drift_table, n_append=growth_rows, seed=999)))
        elif i + 1 > drift_at and churn_every and churn_table:
            since_churn += 1
            if since_churn >= churn_every:
                since_churn = 0
                out.append(Arrival(t, delta=DeltaBatch(
                    churn_table, n_append=churn_rows, seed=1000 + i)))
    return out


# --------------------------------------------------------------------- QoS
class FixedPredictor:
    """Deterministic predictor stub: straggler-shaped queries are slow."""

    def predict_query(self, query):
        return 300.0 if query.name.startswith("straggler") else 1.0


def qos_setup():
    """The standard two-tenant QoS fixture: a weighted 'gold' tenant with
    a tight SLO and a rate-limited 'bulk' tenant, admission driven by the
    FixedPredictor + default degradation ladder."""
    from repro.serve.qos import (DegradationLadder, QoSAdmission,
                                 TenantRegistry, TenantSpec)
    reg = TenantRegistry([
        TenantSpec("gold", weight=2.0, slo=40.0, cache_bytes=8 << 20),
        TenantSpec("bulk", weight=1.0, rate=1.5, burst=2, slo=300.0)])
    adm = QoSAdmission(reg, predictor=FixedPredictor(),
                       ladder=DegradationLadder())
    return reg, adm


def qos_stream(wl, seed: int = 31) -> List[Arrival]:
    """Two tenants' merged open-loop traffic with one hopeless monster
    (a straggler behind gold's tight 40s SLO) swapped in at position 4."""
    from repro.serve.driver import TenantTraffic, multi_tenant_stream
    fast = fast_subset(wl)
    stream = multi_tenant_stream([
        TenantTraffic("gold", fast[:4], rate=3.0, n_queries=10, slo=40.0,
                      seed=seed),
        TenantTraffic("bulk", fast[4:8] or fast, rate=3.0, n_queries=10,
                      slo=300.0, seed=seed + 1)])
    for i, a in enumerate(stream):
        if i == 4:
            a.query, a.tenant = straggler_query(), "gold"
            a.deadline = a.t + 40.0
    return stream
