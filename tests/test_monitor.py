"""The online SLO watchdog (serve.obs.monitor/anomaly/rca): detectors
are deterministic streaming state machines with warmup/cooldown, the
plan ledger holds the swap counterfactual, a served drift outage yields
a detected incident with the RIGHT root cause, monitor-on with alerts
unwired is completion-bit-identical, and the wired alert path actually
heals (alert-driven re-ANALYZE un-arms the stale-stats trap)."""
import json

import numpy as np
import pytest

from scenarios import fast_query, fresh_db, qos_setup, qos_stream, trap_query

from repro.core.encoding import WorkloadMeta
from repro.serve.deltas import DeltaBatch
from repro.serve.obs import (AlertHooks, CusumDetector, DetectorBank,
                             EwmaDetector, Incident, MonitorConfig,
                             PlanLedger, SloMonitor, Tracer)
from repro.serve.obs.rca import Hypothesis, attribute
from repro.serve.scheduler import Arrival
from repro.serve.service import QueryService
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.workloads import Workload


# --------------------------------------------------------------- detectors
def test_ewma_detector_warmup_spike_and_cooldown():
    det = EwmaDetector(alpha=0.25, z=4.0, min_n=5, cooldown=3,
                       direction="high")
    for i in range(5):                        # warmup: never alerts
        assert det.observe(float(i), 10.0 + 0.1 * (i % 2)) is None
    base = det.mean
    a = det.observe(5.0, 50.0)                # spike
    assert a is not None and a.kind == "ewma" and a.direction == "high"
    assert a.value == 50.0 and a.score > 4.0
    # the spike was NOT folded: the baseline still reflects ~10
    assert det.mean == base
    # cooldown mutes, but folds — a durable shift becomes the new normal
    for t in (6.0, 7.0, 8.0):
        assert det.observe(t, 50.0) is None
    assert det.mean > base
    # direction is respected: a drop is not "high"
    low = EwmaDetector(min_n=3, direction="high")
    for i in range(6):
        low.observe(float(i), 10.0)
    assert low.observe(9.0, -100.0) is None
    # ... but a "low" detector fires on it
    lo = EwmaDetector(min_n=3, direction="low")
    for i in range(6):
        lo.observe(float(i), 10.0)
    got = lo.observe(9.0, -100.0)
    assert got is not None and got.direction == "low"


def test_cusum_detector_catches_slow_drift_and_resets():
    det = CusumDetector(alpha=0.1, k=0.5, h=4.0, min_n=4, cooldown=2,
                        min_sigma=0.5, direction="high")
    for i in range(8):
        assert det.observe(float(i), 0.0) is None
    # a level shift one sigma up: never z-alertable, but S accumulates
    alerts = []
    for i in range(40):
        a = det.observe(10.0 + i, 1.0)
        if a is not None:
            alerts.append(a)
    assert alerts and alerts[0].kind == "cusum"
    # S reset on alert: the next alert needs re-accumulation (cooldown 2,
    # and the folding baseline adapts, so alerts THIN OUT over time)
    if len(alerts) > 1:
        assert alerts[1].t - alerts[0].t > 2


def test_detector_bank_routes_by_prefix_and_isolates_series():
    bank = DetectorBank({"p99": lambda: EwmaDetector(min_n=3, z=3.0,
                                                     min_sigma=0.1)})
    for i in range(6):
        bank.observe("p99[a]", float(i), 1.0)
        bank.observe("p99[b]", float(i), 1.0)
        bank.observe("unwatched", float(i), 1.0)
    a = bank.observe("p99[a]", 6.0, 100.0)
    assert a is not None and a.metric == "p99[a]"
    # tenant b's baseline is independent — no cross-talk, no alert
    assert bank.observe("p99[b]", 6.0, 1.0) is None
    # unknown prefixes are ignored, not errors
    assert bank.observe("unwatched", 6.0, 1e9) is None
    assert [x.metric for x in bank.anomalies] == ["p99[a]"]
    bank.reset()
    assert bank.anomalies == [] and bank.detectors == {}


# ------------------------------------------------------------- plan ledger
def test_plan_ledger_regression_counterfactual():
    led = PlanLedger(band_width=1)
    band = (("cast_info", 0),)
    for lat in (1.0, 1.2, 0.9):
        led.observe(1, "q7", band, lat, False)
    led.observe(2, "q7", band, 10.0, True)
    reg = led.regression(2, "q7", band)
    assert reg is not None and reg["same_band"]
    assert reg["prior_step"] == 1
    assert reg["ratio"] == pytest.approx(10.0 / np.mean([1.0, 1.2, 0.9]),
                                         rel=1e-3)
    # no prior step -> no counterfactual; unseen key -> None
    assert led.regression(1, "q7", band) is None
    assert led.regression(2, "q9", band) is None
    # prior stats below min_n don't count
    led.observe(1, "q8", band, 1.0, False)
    led.observe(2, "q8", band, 9.0, False)
    assert led.regression(2, "q8", band, min_n=2) is None
    # a different band still serves as an (off-band) counterfactual
    band2 = (("cast_info", 1),)
    led.observe(2, "q7", band2, 10.0, False)
    reg2 = led.regression(2, "q7", band2)
    assert reg2 is not None and not reg2["same_band"]
    rows = led.rows()
    assert {r["template"] for r in rows} == {"q7", "q8"}
    assert rows == json.loads(json.dumps(rows))
    led.reset()
    assert len(led) == 0


# ------------------------------------------------------------ rca gating
def test_rca_causes_are_event_gated():
    """No swap event -> no policy_swap hypothesis, however regressed the
    ledger looks; a quiet log leaves only the unknown floor."""
    rec = {"seq": 0, "tenant": "a", "template": "q", "t": 10.0,
           "arrival_t": 9.0, "latency": 1.0, "failed": False,
           "failure_kind": "", "fail_kinds": (), "attempts": 1,
           "recovered": False, "step": 2, "band": (),
           "phases": {"queue": 0.2, "execute": 0.8, "retry": 0.0,
                      "hedge": 0.0}}
    hyps = attribute(tenant="a", metric_label="p99", window=[rec],
                     baseline=[], events=[], ledger=None)
    assert [h.cause for h in hyps] == ["unknown"]

    class Ev:
        def __init__(self, kind, t, attrs):
            self.kind, self.t, self.attrs = kind, t, attrs

    hyps = attribute(tenant="a", metric_label="p99", window=[rec],
                     baseline=[],
                     events=[Ev("policy_swap", 9.5,
                                {"from_step": 1, "to_step": 2})],
                     ledger=None)
    assert hyps[0].cause == "policy_swap" and "v2" in hyps[0].summary
    assert hyps[-1].cause == "unknown"      # floor always present
    assert all(h.as_dict() == json.loads(json.dumps(h.as_dict()))
               for h in hyps)


# ----------------------------------------- served outage: detect + attribute
_TRAP_CLUSTER = ClusterModel(materialize_cap=1_500_000, timeout=60.0,
                             oom_charge="detect", oom_spill_penalty=5.0)
_GROWTH_X = 24
_DRIFT_AT = 12


def _watch_cfg():
    return MonitorConfig(window=8, min_warm=4, min_n=5, cooldown=4,
                         merge_gap=8, lookback=10, baseline_max=48)


def _drift_queries():
    return ([trap_query(i, 1940 + 5 * i) for i in range(3)],
            [fast_query(i) for i in range(5)])


def _replan_agent():
    """Stats-DRIVEN planner over the scenario's templates: on the stale
    catalog it walks into the blown join; fresh stats un-arm the trap
    (what the alert path exploits)."""
    from repro.baselines import CboReplanAgent
    traps, fasts = _drift_queries()
    wl = Workload(name="watchdog", max_tables=3, train=traps + fasts,
                  test=[])
    return CboReplanAgent(WorkloadMeta.from_workload(wl), max_steps=3)


def _drift_world():
    """bench_drift's stale-stats shape: movie_info shrunk young, the
    catalog ANALYZEd post-shrink — in sync until the growth delta lands,
    after which every trap OOMs under the cap until a re-ANALYZE."""
    from repro.sql.catalog import analyze
    from repro.serve.deltas import apply_delta
    db = fresh_db(scale=0.06, seed=0)
    apply_delta(db, DeltaBatch("movie_info", delete_frac=0.9, seed=7))
    db.stats = analyze(db, rng=np.random.default_rng(0))
    return db, Estimator(db, db.stats)


def _drift_stream(db, n=36, rate=2.0, seed=11):
    rng = np.random.default_rng(seed)
    traps, fasts = _drift_queries()
    mi_rows = db.table("movie_info").nrows       # post-shrink
    t, out = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        q = traps[(i // 3) % 3] if i % 3 == 0 else fasts[i % 5]
        out.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31)),
                           deadline=t + 30.0))
        if i + 1 == _DRIFT_AT:
            out.append(Arrival(t, delta=DeltaBatch(
                "movie_info", n_append=_GROWTH_X * mi_rows, seed=999)))
    return out


def _drift_serve(*, monitor=None, hooks=(), n_lanes=2):
    db, est = _drift_world()
    stream = _drift_stream(db)
    svc = QueryService(db, _replan_agent(), est=est, n_lanes=n_lanes,
                       cluster=_TRAP_CLUSTER, hooks=list(hooks),
                       monitor=monitor)
    comps, stats = svc.run(stream)
    return comps, stats, svc, stream


def test_monitor_detects_and_attributes_drift_outage():
    mon = SloMonitor(config=_watch_cfg())
    comps, stats, svc, stream = _drift_serve(monitor=mon)
    t_drift = next(a.t for a in stream if a.delta is not None)
    assert any(c.result.failed for c in comps), "trap must be armed"

    # one record per completion (in FINISH order — the monitor is an
    # on_complete hook); phases partition each latency exactly
    by_seq = {c.seq: c for c in comps}
    assert sorted(r["seq"] for r in mon.records) == sorted(by_seq)
    for r in mon.records:
        c = by_seq[r["seq"]]
        assert sum(r["phases"].values()) == pytest.approx(c.latency,
                                                          abs=1e-9)
    assert len(mon.ledger) > 0

    # detected: an incident opens after the delta lands, and RCA blames
    # drift on the grown table — not the (absent) swap/faults/load causes
    incs = [i for i in mon.incidents if i.t_open >= t_drift]
    assert incs, "post-drift outage must be detected"
    inc = incs[0]
    assert inc.closed                      # finalize() sealed it
    assert inc.top is not None and inc.top.cause == "stats_drift"
    assert "movie_info" in inc.top.evidence.get("tables", ())
    causes = [h.cause for h in inc.hypotheses]
    assert "policy_swap" not in causes and "fault_burst" not in causes

    # the tracer's event log carries the full lifecycle (report renders
    # from the JSONL alone) and the flight recorder snapped the incident
    tracer = svc.scheduler.obs
    kinds = [e.kind for e in tracer.events]
    for k in ("anomaly", "incident_open", "incident_rca",
              "incident_close"):
        assert k in kinds
    assert any(d["reason"] == f"incident:{inc.id}"
               for d in tracer.flight.dumps)
    closes = [e for e in tracer.events if e.kind == "incident_close"
              and e.attrs["id"] == inc.id]
    assert closes and closes[0].attrs["top_cause"] == "stats_drift"

    # watchdog counters surface through the service stats
    assert stats.n_incidents == len(mon.incidents) > 0
    assert stats.n_anomalies == sum(mon.n_anomalies.values()) > 0


def test_monitor_on_is_bit_identical_and_reset_clears():
    def sig(comps):
        return [(c.seq, c.admit_t, c.finish_t, c.lane, c.attempts,
                 c.result.failed) for c in comps]

    off, _, _, _ = _drift_serve()
    mon = SloMonitor(config=_watch_cfg())
    on, _, svc, _ = _drift_serve(monitor=mon)
    assert sig(off) == sig(on)             # the watchdog only watches
    assert mon.records and mon.incidents

    svc.reset_stats(clear_entries=True)
    assert mon.records == [] and mon.incidents == []
    assert len(mon.ledger) == 0 and mon.bank.detectors == {}
    assert mon.totals() == (0, 0) and mon._open is None


def test_tenant_stats_carry_watchdog_counters(job_workload, agent):
    """Pinned JSON surface: per-tenant n_anomalies/n_incidents ride the
    TenantStats blob and agree with the monitor's own counters."""
    db = fresh_db(scale=0.05, seed=0)
    reg, adm = qos_setup()
    mon = SloMonitor(config=MonitorConfig(window=6, min_warm=3, min_n=4,
                                          cooldown=3, merge_gap=6,
                                          lookback=8))
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       policy="edf", tenants=reg, admission=adm,
                       monitor=mon)
    _, stats = svc.run(qos_stream(job_workload))
    d = stats.as_dict()
    assert d == json.loads(json.dumps(d))
    assert {"n_anomalies", "n_incidents"} <= set(d)
    assert d["n_anomalies"] == sum(mon.n_anomalies.values())
    assert d["n_incidents"] == sum(mon.n_incidents.values())
    for name, td in d["per_tenant"].items():
        assert {"n_anomalies", "n_incidents"} <= set(td)
        assert (td["n_anomalies"], td["n_incidents"]) == \
            mon.tenant_counts(name)
    assert sum(td["n_incidents"] for td in d["per_tenant"].values()) <= \
        d["n_incidents"]                   # global-series incidents extra


# ------------------------------------------------------------ alert hooks
class _Sink:
    """Duck-typed breaker/drift stand-in: `ret` mimics the real return
    (breaker -> tripped bool, drift -> tuple of scheduled tables)."""

    def __init__(self, ret=True):
        self.calls, self.ret = [], ret

    def note_external_evidence(self, *a, **kw):
        self.calls.append((a, kw))
        return self.ret


class _Comp:
    seq, finish_t = 7, 42.0


def _incident(cause, score, evidence=None):
    inc = Incident(1, "b", "p99[b]", 10.0, 5)
    inc.hypotheses = [Hypothesis(cause, score, f"{cause} it was",
                                 evidence or {})]
    return inc


def test_alert_hooks_route_once_and_respect_min_score():
    brk, drf = _Sink(), _Sink(ret=("cast_info",))
    seen = []
    hooks = AlertHooks(breaker=brk, drift=drf, on_incident=seen.append,
                       min_score=2.0)
    inc = _incident("policy_swap", 3.0)
    hooks.fire(inc, _Comp())
    hooks.fire(inc, _Comp())               # same incident: sinks fire once
    assert len(brk.calls) == 1 and brk.calls[0][0] == (7, "policy_swap it was")
    assert drf.calls == [] and len(seen) == 1
    assert hooks.log == [{"sink": "breaker", "incident": 1,
                          "tripped": True}]

    inc2 = _incident("stats_drift", 2.5, {"tables": ["cast_info"]})
    hooks.fire(inc2, _Comp())
    assert len(drf.calls) == 1
    assert drf.calls[0][0][0] == ["cast_info"]
    assert drf.calls[0][1]["reason"] == "stats_drift it was"

    weak = AlertHooks(breaker=_Sink(), drift=_Sink(ret=()), min_score=2.0)
    weak.fire(_incident("policy_swap", 1.0), _Comp())
    assert weak.breaker.calls == [] and weak.log == []
    # causes route to their matching sink only
    hooks3 = AlertHooks(breaker=_Sink(), drift=_Sink(ret=()))
    hooks3.fire(_incident("hot_tenant", 9.0), _Comp())
    assert hooks3.breaker.calls == [] and hooks3.drift.calls == []


def test_breaker_external_evidence_is_noop_without_watched_swap(tmp_path):
    from repro.learn.policy_store import PolicyStore
    from repro.serve.recover import PolicyBreaker

    store = PolicyStore(str(tmp_path), probe=[], mode="gate")
    brk = PolicyBreaker(store, object(), window=8, min_post=3)
    assert brk.note_external_evidence(5, "spurious") is False
    assert brk.trips == []


def test_alert_driven_reanalyze_heals_the_drift_outage():
    """End-to-end actuation: monitor detects the stale-stats outage,
    attributes it to movie_info, and the wired DriftController schedules
    an alert re-ANALYZE barrier — after which the stats-driven planner
    stops walking into the trap. Unwired, the traps fail to stream end."""
    from repro.serve.drift import DriftController

    unwired, _, _, _ = _drift_serve(monitor=SloMonitor(config=_watch_cfg()))

    ctl = DriftController()                # RefreshPolicy("never"): the
    alerts = AlertHooks(drift=ctl)         # alert path is the ONLY actuator
    mon = SloMonitor(config=_watch_cfg(), alerts=alerts)
    wired, _, svc, _ = _drift_serve(monitor=mon, hooks=[ctl])

    assert any(e["sink"] == "drift" and "movie_info" in e["tables"]
               for e in alerts.log)
    labels = [lbl for _, lbl in svc.scheduler.task_log]
    assert any(lbl.startswith("re-analyze[alert]:") and "movie_info" in lbl
               for lbl in labels)
    assert ctl.stats.refresh_events >= 1

    fails = lambda cs: sum(c.result.failed for c in cs)
    assert fails(unwired) > fails(wired)   # the alert path healed traffic
    # the tail is clean: after the refresh barrier no trap fails again
    t_fix = next(t for t, lbl in svc.scheduler.task_log
                 if lbl.startswith("re-analyze[alert]:"))
    assert fails([c for c in wired if c.admit_t > t_fix]) == 0
