"""Online serving subsystem invariants: LRU stage cache (eviction, stats,
O(1) version-tag invalidation, bit-for-bit equality with cache-off runs),
async lane scheduling (seeded async == seeded serial; stragglers do not
block other lanes; lockstep remains a reproducible special case), the
delta write barrier, and the service façade's metrics.

Shared scenario builders (fresh dbs, fast/straggler/mi-join queries,
barrier streams) live in tests/scenarios.py; the `agent` fixture is the
session-scoped one from conftest.py.
"""
import numpy as np

from scenarios import (barrier_stream, fast_query, fresh_db, mi_join_query,
                       straggler_mix_stream, straggler_query)

from repro.core.rollout import rollout
from repro.serve.cache import StageCache
from repro.serve.deltas import DeltaBatch, apply_delta
from repro.serve.driver import open_loop_stream
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.serve.service import QueryService
from repro.sql.cbo import Estimator
from repro.sql.executor import Executor, run_adaptive
from repro.sql.plans import syntactic_plan


# ------------------------------------------------------------- stage cache
def test_stage_cache_lru_eviction_not_clear_all():
    c = StageCache(max_bytes=100, max_entry_bytes=100)
    for i in range(4):
        assert c.put(("sig", i), f"entry{i}", 30)
    # 4*30 > 100: the LRU entry (sig 0) was evicted, the rest survive —
    # the old dict dropped EVERYTHING on overflow
    assert c.stats.evictions == 1 and len(c) == 3
    assert c.get(("sig", 0)) is None
    assert c.get(("sig", 1)) == "entry1"
    c.put(("sig", 4), "entry4", 30)          # now 2 is LRU (1 was touched)
    assert c.get(("sig", 2)) is None and c.get(("sig", 1)) == "entry1"
    assert c.bytes <= c.max_bytes
    assert not c.put("huge", "x", 101)       # oversized: never admitted
    s = c.stats.as_dict()
    assert s["hits"] == 2 and s["misses"] == 2 and s["evictions"] == 2


def test_stage_cache_oversized_entry_and_zero_budget():
    # an entry larger than the WHOLE budget is refused outright: no
    # eviction storm, resident entries untouched, counters unchanged
    c = StageCache(max_bytes=100, max_entry_bytes=1000)
    assert c.put(("a",), "x", 60)
    assert not c.put(("big",), "y", 101)
    assert len(c) == 1 and c.bytes == 60 and c.stats.evictions == 0
    assert c.get(("a",)) == "x"
    # budget = 0: nothing with real bytes is ever admitted or evicted
    z = StageCache(max_bytes=0)
    assert not z.put(("s",), "x", 1)
    assert len(z) == 0 and z.bytes == 0 and z.stats.evictions == 0
    assert z.get(("s",)) is None and z.stats.misses == 1


def test_stage_cache_eviction_counter_consistency():
    """admitted - evicted == resident at every point, including refreshes
    of an existing signature (which must not double-count bytes)."""
    c = StageCache(max_bytes=100, max_entry_bytes=100)
    admitted = sum(c.put(("sig", i), i, 27) for i in range(20))
    assert admitted == 20
    assert admitted - c.stats.evictions == len(c)
    assert c.bytes == 27 * len(c) <= c.max_bytes
    # refreshing a resident sig with a new size replaces, never duplicates
    # — and is not an eviction: the counters keep adding up
    sig = next(iter(c._entries))
    before, evictions_before = len(c), c.stats.evictions
    c.put(sig, "new", 10)
    assert len(c) == before
    assert c.bytes == 27 * (len(c) - 1) + 10
    assert c.stats.evictions == evictions_before
    assert admitted - c.stats.evictions == len(c)


def test_stage_cache_reset_stats_keeps_entries():
    """reset_stats zeroes the counters without touching residency — the
    between-runs measurement seam."""
    c = StageCache(max_bytes=100, max_entry_bytes=100)
    c.put(("a",), "x", 30)
    assert c.get(("a",)) == "x" and c.get(("b",)) is None
    assert c.stats.hits == 1 and c.stats.misses == 1
    c.reset_stats()
    assert c.stats.as_dict() == {"hits": 0, "misses": 0, "evictions": 0,
                                 "invalidations": 0, "hit_rate": 0.0}
    assert len(c) == 1 and c.get(("a",)) == "x"   # entries survived


def test_executor_exposes_cache_stats_and_hits(job_workload):
    db = fresh_db(scale=0.05)
    est = Estimator(db, db.stats)
    q = job_workload.test[0]
    ex = Executor(db)
    assert ex.cache_stats is not None and ex.cache_stats.hits == 0
    r1 = run_adaptive(db, q, syntactic_plan(q), est)
    misses_after_first = ex.cache_stats.misses
    r2 = run_adaptive(db, q, syntactic_plan(q), est)
    assert ex.cache_stats.hits > 0, "replaying a query must hit the cache"
    assert ex.cache_stats.misses == misses_after_first
    assert r1.latency == r2.latency
    assert [s.out_rows for s in r1.stages] == [s.out_rows for s in r2.stages]
    assert Executor(db, reuse_stages=False).cache_stats is None


def test_executor_eviction_under_tiny_budget(job_workload):
    db = fresh_db(scale=0.05)
    db._stage_cache = StageCache(max_bytes=64 * 1024)
    est = Estimator(db, db.stats)
    for q in job_workload.test[:6]:
        run_adaptive(db, q, syntactic_plan(q), est)
    st = db._stage_cache.stats
    assert st.evictions > 0, "tiny budget must evict"
    assert len(db._stage_cache) > 0, "eviction is LRU, not clear-all"
    assert db._stage_cache.bytes <= 64 * 1024


# ----------------------------------------------------- delta invalidation
def test_invalidation_recomputes_bit_for_bit_vs_cache_off():
    db = fresh_db(scale=0.08)
    est = Estimator(db, db.stats)
    q = mi_join_query()
    r1 = run_adaptive(db, q, syntactic_plan(q), est)
    r2 = run_adaptive(db, q, syntactic_plan(q), est)       # warm: cache hit
    assert [s.out_rows for s in r2.stages] == [s.out_rows for s in r1.stages]
    hits_before = db._stage_cache.stats.hits
    assert hits_before > 0

    counts = apply_delta(db, DeltaBatch("movie_info", n_append=2000, seed=1))
    assert counts["appended"] == 2000
    assert db.table_version("movie_info") == 1
    assert db._stage_cache.stats.invalidations == 1

    r3 = run_adaptive(db, q, syntactic_plan(q), est)       # post-delta
    ref = run_adaptive(db, q, syntactic_plan(q), est, reuse_stages=False)
    # bit-for-bit vs cache-off on the NEW data — a stale cached stage
    # would differ, because appended rows join with existing titles
    assert r3.latency == ref.latency
    assert r3.total_shuffles == ref.total_shuffles
    assert [s.out_rows for s in r3.stages] == [s.out_rows for s in ref.stages]
    assert [s.out_rows for s in r3.stages] != [s.out_rows for s in r1.stages]


def test_delta_delete_and_append_roundtrip():
    db = fresh_db(scale=0.05)
    t = db.table("movie_keyword")
    n0 = t.nrows
    counts = apply_delta(db, DeltaBatch("movie_keyword", n_append=100,
                                        delete_frac=0.5, seed=2))
    assert t.nrows == n0 + 100 - counts["deleted"]
    assert counts["deleted"] > 0
    assert db.table_version("movie_keyword") == 1
    # FKs still live: every movie_id points at an existing title row
    assert t.columns["movie_id"].max() < db.table("title").nrows


# --------------------------------------------------------- lane scheduler
def test_async_scheduler_matches_seeded_serial(job_db, job_workload,
                                               estimator, agent):
    qs = job_workload.test[:6]
    seeds = [11, 22, 33, 44, 55, 66]
    serial = [rollout(job_db, q, estimator, agent, stage=3, explore=True,
                      key=s) for q, s in zip(qs, seeds)]
    sched = LaneScheduler(job_db, estimator, agent, n_lanes=3,
                          explore=True, policy="async")
    comps = sched.run([Arrival(0.3 * i, query=q, seed=s)
                       for i, (q, s) in enumerate(zip(qs, seeds))])
    assert [c.seq for c in comps] == list(range(6))
    for s, c in zip(serial, comps):
        assert s.actions == c.traj.actions
        assert s.t_execute == c.traj.t_execute
        assert s.rewards == c.traj.rewards
        np.testing.assert_allclose(s.logps, c.traj.logps, atol=1e-6)


def test_scheduler_window_does_not_change_results(job_db, job_workload,
                                                  estimator, agent):
    qs = job_workload.test[:5]
    streams = []
    for window in (None, 0.0, 1.0):
        sched = LaneScheduler(job_db, estimator, agent, n_lanes=2,
                              explore=True, policy="async", window=window)
        streams.append(sched.run([Arrival(0.5 * i, query=q, seed=i)
                                  for i, q in enumerate(qs)]))
    for comps in streams[1:]:
        for a, b in zip(streams[0], comps):
            assert a.traj.actions == b.traj.actions
            assert a.finish_t == b.finish_t and a.admit_t == b.admit_t


def test_straggler_does_not_block_other_lanes(job_workload, agent):
    db = fresh_db(scale=0.1)
    est = Estimator(db, db.stats)
    strag_q = straggler_query()
    # precondition: the straggler really dominates (OOM -> timeout charge)
    r_strag = run_adaptive(db, strag_q, syntactic_plan(strag_q), est)
    fast0 = fast_query(0)
    r_fast = run_adaptive(db, fast0, syntactic_plan(fast0), est)
    assert r_strag.latency > 10 * r_fast.latency

    def serve(policy):
        sched = LaneScheduler(db, est, agent, n_lanes=2, explore=False,
                              policy=policy, window=0.0)
        return sched.run(straggler_mix_stream(6))

    a = serve("async")
    strag = a[0]
    # every fast query finished (virtually) before the straggler...
    assert all(c.finish_t < strag.finish_t for c in a[1:])
    # ...because none of them ever waited behind it: the straggler holds
    # exactly one lane while the other lane streams through all 6
    assert all(c.lane != strag.lane for c in a[1:])
    # step-count: the straggler got at most its hook-budget of decisions,
    # yet the scheduler kept ticking for everyone else
    assert len(strag.traj.actions) <= agent.cfg.max_steps
    fast_steps = sum(len(c.traj.actions) for c in a[1:])
    assert fast_steps >= 6

    ls = serve("lockstep")
    strag_l = ls[0]
    done_before_async = sum(c.finish_t < strag.finish_t for c in a[1:])
    done_before_lock = sum(c.finish_t < strag_l.finish_t for c in ls[1:])
    # lockstep barriers every later wave behind the straggler
    assert done_before_async == 6 and done_before_lock <= 1
    p99 = lambda comps: float(np.percentile([c.latency for c in comps], 99))
    assert p99(a) < p99(ls), "async must beat lockstep on a straggler mix"


def test_lockstep_policy_matches_rollout_batch(job_db, job_workload,
                                               estimator, agent):
    from repro.core.vec_rollout import rollout_batch
    qs = job_workload.test[:4]
    trajs = rollout_batch(job_db, qs, estimator, agent, explore=True,
                          seeds=[7, 8, 9, 10])
    sched = LaneScheduler(job_db, estimator, agent, n_lanes=4, explore=True,
                          policy="lockstep")
    comps = sched.run([Arrival(0.0, query=q, seed=s)
                       for q, s in zip(qs, [7, 8, 9, 10])])
    for t, c in zip(trajs, comps):
        assert t.actions == c.traj.actions
        assert t.t_execute == c.traj.t_execute


# ------------------------------------------------------- delta write barrier
def test_delta_write_barrier_orders_queries(job_workload, agent):
    db = fresh_db(scale=0.08)
    est = Estimator(db, db.stats)
    stream = barrier_stream(mi_join_query("q_mi_barrier"))
    sched = LaneScheduler(db, est, agent, n_lanes=2, explore=False,
                          policy="async")
    comps = sched.run(stream)
    assert len(sched.delta_log) == 1
    t_apply = sched.delta_log[0][0]
    pre, post = comps[:2], comps[2:]
    assert all(c.finish_t <= t_apply for c in pre), "barrier drains in-flight"
    assert all(c.admit_t >= t_apply for c in post), "later queries wait"
    # queries behind the barrier saw the appended rows: stage cardinalities
    # differ from the pre-delta executions of the SAME query
    rows = lambda c: [s.out_rows for s in c.result.stages]
    assert rows(post[0]) != rows(pre[0])
    assert rows(post[0]) == rows(post[1])


# ---------------------------------------------------------------- service
def test_query_service_empty_stream(job_workload, agent):
    """An empty arrival stream must yield zeroed stats, not a divide by
    zero (qps, percentiles, mean decide batch)."""
    db = fresh_db(scale=0.05)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2)
    comps, stats = svc.run([])
    assert comps == []
    assert stats.n_completed == 0 and stats.n_failed == 0
    assert stats.qps == 0.0 and stats.latency_p99 == 0.0
    assert stats.mean_decide_batch == 0.0 and stats.ticks == 0
    assert 0.0 <= stats.cache["hit_rate"] <= 1.0
    # and run_queries of an empty batch goes through the same path
    comps, stats = svc.run_queries([])
    assert comps == [] and stats.n_completed == 0


def test_query_service_stats_and_driver(job_workload, agent):
    db = fresh_db(scale=0.08)
    est = Estimator(db, db.stats)
    stream = open_loop_stream(job_workload.test[:6], rate=4.0,
                              n_queries=10, seed=5)
    assert len(stream) == 10
    assert all(stream[i].t <= stream[i + 1].t for i in range(9))
    svc = QueryService(db, agent, est=est, n_lanes=3, policy="async")
    comps, stats = svc.run(stream)
    assert stats.n_completed == 10
    assert stats.qps > 0 and stats.latency_p99 >= stats.latency_p50 > 0
    assert 0.0 <= stats.cache["hit_rate"] <= 1.0
    assert stats.ticks == len(svc.scheduler.decide_sizes)
    # same trace through lockstep: identical per-query service times,
    # scheduling differences only show up in queueing latency
    svc2 = QueryService(db, agent, est=est, n_lanes=3, policy="lockstep")
    comps2, _ = svc2.run(stream)
    for a, b in zip(comps, comps2):
        assert a.result.latency == b.result.latency
        assert a.traj.actions == b.traj.actions


def test_query_service_reset_stats_between_runs(job_workload, agent):
    """Consecutive runs on one service ACCUMULATE cache counters (the
    executor state is shared); reset_stats(clear_entries=True) makes the
    second run's stats independently measurable — and identical to the
    first run's on an unmutated database."""
    db = fresh_db(scale=0.05)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2)
    stream = open_loop_stream(job_workload.test[:4], rate=4.0,
                              n_queries=6, seed=9)
    _, s1 = svc.run(stream)
    _, s2 = svc.run(stream)           # warm cache + counters carry over
    assert s2.cache["hits"] > s1.cache["hits"]
    svc.reset_stats(clear_entries=True)
    assert len(svc.cache) == 0
    _, s3 = svc.run(stream)           # cold again: full independent rerun
    d1, d3 = s1.as_dict(), s3.as_dict()
    d1.pop("hook_seconds"), d3.pop("hook_seconds")   # host wall time
    assert d3 == d1
    # counters-only reset keeps entries resident: same completions, all
    # prior misses now hit
    svc.reset_stats()
    assert len(svc.cache) > 0
    _, s4 = svc.run(stream)
    assert s4.cache["misses"] == 0 and s4.cache["hits"] > 0
    assert s4.n_completed == s1.n_completed
