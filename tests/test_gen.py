"""The world generator's determinism + validity suite (repro.gen).

Three layers of guarantees:

  * bit-identity of the refactored hand-built worlds: `sql.datagen`'s
    JOB/STACK builders are now thin `SchemaSpec` instances interpreted
    by `make_db_from_spec` — the pinned sha256 goldens here were
    computed on the PRE-refactor builders, so any drift in the draw
    sequence (column hoisting, fk domains, size_with cascades, analyze
    seeding) fails loudly;

  * sampler determinism: same seed => bit-identical schema, workload
    (queries + constants), stream profile and arrival stream, pinned by
    short sha fingerprints so cross-platform RNG drift is caught;

  * validity properties over >= 100 sampled worlds: acyclic FK DAGs,
    joinable (connected, alias-consistent) templates, predicate
    constants inside their column's declared domain (no
    empty-result-by-construction), disjoint train/test instantiation
    streams, delta targets restricted to delete-safe tables.
"""
import hashlib

import numpy as np
import pytest

from repro.gen import seeds as genseeds
from repro.gen.queries import make_gen_workload
from repro.gen.schema import FAMILIES, sample_schema
from repro.gen.spec import assert_valid, delete_safe_tables, join_edges
from repro.gen.streams import StreamProfile, build_stream
from repro.gen.world import sample_world
from repro.sql import datagen
from repro.sql.workloads import make_workload


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def _db_hash(db) -> str:
    h = hashlib.sha256()
    h.update(db.name.encode())
    for tname in db.tables:               # insertion order is identity
        t = db.tables[tname]
        h.update(tname.encode())
        for cname, arr in t.columns.items():
            h.update(cname.encode())
            h.update(np.ascontiguousarray(arr).tobytes())
    for tname in sorted(db.stats.tables):
        ts = db.stats.tables[tname]
        h.update(f"{tname}:{ts.nrows!r}".encode())
        for cname in sorted(ts.columns):
            c = ts.columns[cname]
            h.update(f"{cname}:{c.n_distinct!r}:{c.min_val!r}:"
                     f"{c.max_val!r}".encode())
    return h.hexdigest()


def _wl_text(wl) -> str:
    return repr([(q.name, q.relations, q.conds)
                 for q in wl.train + wl.test])


def _stream_text(stream) -> str:
    return repr([(round(a.t, 9), a.tenant, getattr(a.query, "name", None),
                  None if a.delta is None else
                  (a.delta.table, a.delta.n_append, a.delta.delete_frac,
                   a.delta.seed))
                 for a in stream])


# ------------------------------------------- hand-built world bit-identity
def test_job_like_bit_identical_to_pre_refactor():
    """Goldens computed on the hand-built (pre-SchemaSpec) builders."""
    assert _db_hash(datagen.make_job_like(scale=0.06, seed=0)) == \
        "84f2bea1f1a3d03654b92ac679eebacb5bf2900730663dce80f1c1c8ada7a3c8"
    assert _db_hash(datagen.make_job_like(scale=0.05, seed=3,
                                          year_max=1980)) == \
        "0aca0165fceee09d9b2882f4fb37d038dac50b020a8a60d332b87d7112b094ef"


def test_stack_like_bit_identical_to_pre_refactor():
    assert _db_hash(datagen.make_stack_like(scale=0.05, seed=1)) == \
        "136f7636523357f61eb1a468a3a183a7950d019a0fc6d18266c1ba5becb8c23e"


def test_hand_built_workloads_bit_identical():
    """`make_workload` now routes seeds through `gen.seeds` — the query
    streams must be unchanged."""
    def wl_hash(wl):
        h = hashlib.sha256()
        for q in wl.train + wl.test:
            h.update(repr((q.name, q.relations, q.conds)).encode())
        return h.hexdigest()
    assert wl_hash(make_workload("job", 24, 1, seed=7)) == \
        "3e2ad681f2184870bbb115646fa32ef0482a1e082523cb9609d8b93b8a285000"
    assert wl_hash(make_workload("stack", 16, 1, seed=9)) == \
        "9e4c563e7f10897b4154ca27c0e031b6eaa3035586f811a978c204063f19de71"


def test_hand_built_specs_valid():
    assert_valid(datagen.JOB_SPEC)
    assert_valid(datagen.STACK_SPEC)
    assert {"title", "cast_info", "movie_info"} <= \
        {t.name for t in datagen.JOB_SPEC.tables}


# ------------------------------------------------------ seed partitioning
def test_seed_partition_contract():
    tr, te = genseeds.split_train_test(7)
    assert (tr, te) == (7, 7 + genseeds.TRAIN_TEST_SEED_GAP)
    train_r, test_r = genseeds.seed_ranges()
    assert set(train_r).isdisjoint(test_r)
    assert all(genseeds.test_seed(b) in test_r for b in (0, 42, 9999))
    with pytest.raises(AssertionError):
        genseeds.split_train_test(genseeds.TRAIN_TEST_SEED_GAP)
    with pytest.raises(AssertionError):
        make_workload("job", 4, 1, seed=genseeds.TRAIN_TEST_SEED_GAP)


def test_substream_decorrelates_stages():
    """Layer sub-seeds never collide across a wide sweep of world seeds
    (raw seed+k offsets would: world k's stage 1 == world k+1's stage 0)."""
    subs = {genseeds.substream(s, stage)
            for s in range(500) for stage in range(1, 6)}
    assert len(subs) == 500 * 5


# ------------------------------------------------- sampler determinism
def test_schema_sampler_pinned():
    assert _sha(repr(sample_schema(0))) == "4eb7b2f48e11d38a"
    assert _sha(repr(sample_schema(7))) == "2180af6c1aa6fddc"
    # same seed => identical spec (dataclass equality, not just repr)
    assert sample_schema(13) == sample_schema(13)
    # family pin consumes the family draw, so the rest doesn't shift
    fam = sample_schema(13).family
    assert sample_schema(13, family=fam) == sample_schema(13)


def test_query_sampler_pinned():
    spec = sample_schema(7)
    wl = make_gen_workload(spec, 123, n_templates=6, n_train=10,
                           n_test_per_template=1)
    assert _sha(_wl_text(wl)) == "8eda67420e4a14e2"
    wl2 = make_gen_workload(spec, 123, n_templates=6, n_train=10,
                            n_test_per_template=1)
    assert _wl_text(wl) == _wl_text(wl2)


def test_stream_sampler_pinned():
    w = sample_world(9, n_templates=5, n_train=8, t_max=5, n_queries=16,
                     materialize=False)
    assert w.profile.delta_every > 0 and w.profile.burst is not None
    assert _sha(_stream_text(w.stream)) == "0cfe1ecbd90a9b32"
    w2 = sample_world(9, n_templates=5, n_train=8, t_max=5, n_queries=16,
                      materialize=False)
    assert _stream_text(w.stream) == _stream_text(w2.stream)
    inj = w.fault_injector()
    assert inj is not None and inj.window == (3, 7)
    assert w2.fault_injector().seed == inj.seed


def test_world_materialization_pinned():
    w = sample_world(5, n_templates=5, n_train=8, t_max=5, n_queries=16)
    assert w.spec.name == "person577341421"
    assert _db_hash(w.db)[:16] == "a9a00b43c6b84b47"
    w2 = sample_world(5, n_templates=5, n_train=8, t_max=5, n_queries=16)
    assert _db_hash(w.db) == _db_hash(w2.db)


def test_mixed_delta_kinds_cycle():
    """The stream renderer cycles append / update / delete batches over
    the profile's delete-safe targets."""
    spec = sample_schema(0, family="star")
    wl = make_gen_workload(spec, 1, n_templates=4, n_train=8,
                           n_test_per_template=1)
    profile = StreamProfile(
        n_queries=24, rate=4.0, n_tenants=2, slos=(None, 100.0),
        delta_every=4, delta_rows=500, delete_frac=0.1,
        delta_tables=delete_safe_tables(spec), burst=(0.5, 3.0, 4),
        faults=())
    stream = build_stream(wl, profile, seed=3)
    deltas = [a.delta for a in stream if a.delta is not None]
    assert len(deltas) == 6
    kinds = {(d.n_append > 0, d.delete_frac > 0) for d in deltas}
    assert kinds == {(True, False), (True, True), (False, True)}
    assert {d.table for d in deltas} <= set(delete_safe_tables(spec))
    assert [a.t for a in stream] == sorted(a.t for a in stream)


# --------------------------------------------- validity over many worlds
def _domain_of(spec, table, col):
    c = next(c for c in spec.table(table).columns if c.name == col)
    if c.kind == "cat":
        return c.lo, c.hi
    if c.kind == "cat2":
        return 0, max(c.hi_k, c.lo_k)
    if c.kind == "id":
        return 0, spec.table(table).n_rows
    return None                      # fk columns are never filtered


def _check_query_valid(spec, q):
    aliases = {r.alias for r in q.relations}
    assert len(aliases) == len(q.relations), f"{q.name}: duplicate aliases"
    # every join cond references in-query aliases and real columns
    adj = {a: set() for a in aliases}
    for jc in q.conds:
        assert {jc.left, jc.right} <= aliases
        adj[jc.left].add(jc.right)
        adj[jc.right].add(jc.left)
    # connected: no cross products by construction
    seen, todo = set(), [q.relations[0].alias]
    while todo:
        a = todo.pop()
        if a in seen:
            continue
        seen.add(a)
        todo.extend(adj[a])
    assert seen == aliases, f"{q.name}: disconnected join graph"
    # fanout guard: never more than 2 fk children per parent key (a
    # k-spoke hub star blows the materialize cap under EVERY join order)
    spokes = {}
    for jc in q.conds:
        spokes[jc.right] = spokes.get(jc.right, 0) + 1
    assert max(spokes.values()) <= 2, f"{q.name}: hub star {spokes}"
    # filters: real columns, constants inside the declared domain
    for r in q.relations:
        tcols = {c.name for c in spec.table(r.table).columns}
        for f in r.filters:
            assert f.column in tcols
            dom = _domain_of(spec, r.table, f.column)
            assert dom is not None, f"{q.name}: filter on fk {f.column}"
            lo, hi = dom
            if f.op == "in":
                assert all(lo <= v < hi for v in f.value), \
                    f"{q.name}: {r.table}.{f.column} in {f.value} " \
                    f"outside [{lo},{hi})"
            elif f.op == "<=":          # upper bound must keep rows
                assert f.value[0] >= lo
            elif f.op == ">=":          # lower bound must keep rows
                assert f.value[0] < hi
            else:
                raise AssertionError(f"unexpected op {f.op}")


@pytest.mark.parametrize("base", [0, 40, 80])
def test_sampled_worlds_are_valid(base):
    """Schema validity (acyclic FK DAG via assert_valid), joinable
    connected templates, in-domain predicates, disjoint train/test, and
    delete-safe delta targets — over 40 worlds per case (120 total)."""
    fams = set()
    for seed in range(base, base + 40):
        w = sample_world(seed, n_templates=4, n_train=8, t_min=3, t_max=5,
                         n_queries=12, materialize=False)
        assert_valid(w.spec)                       # acyclic, resolvable
        fams.add(w.spec.family)
        assert join_edges(w.spec), "no joinable edges sampled"
        assert delete_safe_tables(w.spec), "no delete-safe table"
        names = [q.name for q in w.workload.train + w.workload.test]
        assert len(names) == len(set(names))
        for q in w.workload.train + w.workload.test:
            _check_query_valid(w.spec, q)
        assert w.workload.max_tables >= 3
        assert len(w.meta.table_index) >= 3
        # stream: sorted, delta targets delete-safe, tenants tagged
        safe = set(delete_safe_tables(w.spec))
        assert [a.t for a in w.stream] == sorted(a.t for a in w.stream)
        for a in w.stream:
            if a.delta is not None:
                assert a.delta.table in safe
            else:
                assert a.tenant.startswith(("t", "burst"))
    assert fams == set(FAMILIES), f"40-world sweep missed a family: {fams}"


def test_generated_world_serves_end_to_end():
    """One sampled world runs through the real scheduler: its stream's
    queries complete, deltas bump versions, and the run replays
    bit-identically (the generator's output is a WORLD, not just data)."""
    from scenarios import NoopServeAgent
    from repro.serve.scheduler import LaneScheduler
    from repro.sql.cbo import Estimator

    def serve():
        w = sample_world(3, n_templates=4, n_train=8, t_min=3, t_max=4,
                         n_queries=10, scale=0.04)
        agent = NoopServeAgent(w.meta)
        sched = LaneScheduler(w.db, Estimator(w.db, w.db.stats), agent,
                              n_lanes=2)
        comps = sched.run(w.stream)
        return w, comps

    w, comps = serve()
    n_q = sum(1 for a in w.stream if a.delta is None)
    n_d = sum(1 for a in w.stream if a.delta is not None)
    assert len(comps) == n_q and n_q > 0 and n_d > 0
    assert all(c.finish_t > c.admit_t >= c.arrival_t for c in comps)
    assert sum(w.db.versions.values()) == n_d
    w2, comps2 = serve()
    assert [(c.seq, c.admit_t, c.finish_t, c.result.latency)
            for c in comps] == \
        [(c.seq, c.admit_t, c.finish_t, c.result.latency) for c in comps2]
