"""End-to-end integration: AQORA trains and evaluates against the engine;
all three baselines run; the planner extension composes bushy plans; the
Plane-B layout knobs lower cleanly on the host mesh."""
import numpy as np
import pytest

from repro.baselines import AutoSteerOptimizer, LeroOptimizer, run_spark_default
from repro.core.agent import AgentConfig
from repro.core.train_loop import evaluate, train_agent
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel


def test_aqora_end_to_end_short(job_db, job_workload):
    agent, logs = train_agent(job_db, job_workload, episodes=8, seed=0,
                              cfg=AgentConfig(), log_every=0)
    assert len(logs) == 8
    res = evaluate(job_db, job_workload.test[:4], agent)
    assert len(res) == 4
    for r in res:
        assert r["latency"] > 0 and np.isfinite(r["plan_time"])
        assert 0 <= len(r["actions"]) <= 3


def test_baselines_run(job_db, job_workload, estimator):
    rng = np.random.default_rng(0)
    q = job_workload.test[0]
    r0 = run_spark_default(job_db, q, estimator)
    assert r0.plan_time == 0.0
    lero = LeroOptimizer(job_db, estimator)
    lero.train_episode(job_workload.train[0])
    r1 = lero.run(q)
    assert r1.plan_time > 0
    ast = AutoSteerOptimizer(job_db, estimator)
    ast.train_episode(job_workload.train[0], rng)
    r2 = ast.run(q)
    assert r2.plan_time > 0


def test_lero_candidates_are_diverse(job_db, estimator, job_workload):
    lero = LeroOptimizer(job_db, estimator)
    # a join-heavy query should yield >1 distinct candidate order
    q = max(job_workload.test, key=lambda q: q.n_relations)
    plans, t_plan = lero.candidates(q)
    assert len(plans) >= 2
    assert t_plan > len(plans) * 0.5      # EXPLAIN cost charged per plan


def test_swap_composes_bushy_plan(job_db, estimator, job_workload):
    """Paper §VI-B1: swapping a completed subtree with a leaf mid-execution
    yields a bushy executed shape."""
    from repro.core.encoding import WorkloadMeta
    from repro.core.agent import AqoraAgent
    from repro.core.rollout import rollout
    meta = WorkloadMeta.from_workload(job_workload)
    cfg = AgentConfig(families=("cbo", "lead", "swap", "noop"))
    agent = AqoraAgent(meta, cfg, seed=3)
    bushy_seen = False
    for q in job_workload.test:
        if q.n_relations < 6:
            continue
        for seed in range(3):
            traj = rollout(job_db, q, estimator, agent, stage=3, explore=True)
            if traj.result.bushy:
                bushy_seen = True
                break
        if bushy_seen:
            break
    assert bushy_seen, "no bushy execution reachable via swap/lead actions"


def test_layout_knobs_lower_on_host_mesh():
    """Every Plane-B knob combination must produce a compilable program."""
    import jax
    from repro.adapt.knobs import LayoutPlan
    from repro.configs import registry
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_step, input_specs, batch_struct
    from repro.sharding import act
    from repro.configs.base import ShapeConfig
    from repro.models import lm
    from repro.optim import adamw_init

    cfg = registry.reduced(registry.get_config("qwen3-8b"))
    shape = ShapeConfig("t", 64, 2, "train")
    mesh = make_host_mesh()
    for layout in (LayoutPlan(), LayoutPlan(attn_mode="heads", remat="dots"),
                   LayoutPlan(attn_mode="none", ce_chunk=32,
                              grad_compress=True)):
        fn = make_train_step(cfg, grad_compress=layout.grad_compress)
        params = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(lambda: adamw_init(params))
        batch = batch_struct(cfg, shape)
        pol = act.ActivationPolicy(attn_mode=layout.attn_mode,
                                   ce_chunk=layout.ce_chunk,
                                   remat=layout.remat)
        with mesh, act.policy(pol):
            lowered = jax.jit(fn).lower(params, opt, batch)
            assert lowered.compile() is not None
