"""Drift control plane invariants: detector determinism and scoring
semantics, RefreshPolicy("never") bit-identical to the PR-4 serving path,
write-barrier re-ANALYZE semantics (stage cache stays correct, catalog
catches up, gate caches are fenced), coverage probes shifting toward
drifted templates, and predictor refit generation fencing.

All scenarios come from tests/scenarios.py; the `agent` fixture is the
session-scoped one from conftest.py.
"""
import numpy as np
import pytest

from scenarios import (drifting_delta_stream, fast_query, fresh_db,
                       make_agent, mi_join_query, straggler_query)

from repro.learn import PolicyStore, ReplayBuffer, TrajectoryHarvester
from repro.serve.deltas import DeltaBatch, apply_delta
from repro.serve.drift import (CoverageProbeSet, DriftController,
                               DriftDetector, RefreshPolicy, TableDrift)
from repro.serve.qos import LatencyPredictor, encode_query
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.serve.service import QueryService
from repro.sql.catalog import analyze, analyze_table
from repro.sql.cbo import Estimator
from repro.sql.executor import run_adaptive
from repro.sql.plans import syntactic_plan


def _drift(table, score, lag=1):
    return TableDrift(table, lag, 1.0, 0.0, 0.0, score)


# --------------------------------------------------------------- detector
def test_detector_zero_lag_scores_zero():
    """A table whose data never changed is NOT stale, however bad the
    execution evidence on it looks — regret there is a policy problem."""
    db = fresh_db(scale=0.05)
    det = DriftDetector()
    det.snapshot(db)
    for _ in range(8):
        det.observe(("title",), regret=3.0, pred_err=2.0)
    d = det.score_table(db, "title")
    assert d.version_lag == 0 and d.score == 0.0
    assert d.regret > 0 and d.pred_err > 0        # evidence is recorded


def test_detector_lag_rows_and_evidence_compose():
    db = fresh_db(scale=0.05)
    det = DriftDetector()
    det.snapshot(db)
    apply_delta(db, DeltaBatch("movie_info", n_append=5000, seed=1))
    base = det.score_table(db, "movie_info")
    assert base.version_lag == 1 and base.rows_ratio > 1.0 and base.score > 0
    # execution evidence AMPLIFIES catalog lag, never replaces it
    det.observe(("movie_info",), regret=2.0, pred_err=1.0)
    amped = det.score_table(db, "movie_info")
    assert amped.score > base.score
    # a second delta raises the lag term
    apply_delta(db, DeltaBatch("movie_info", n_append=100, seed=2))
    assert det.score_table(db, "movie_info").version_lag == 2
    # refresh: lag returns to zero, evidence windows restart
    det.note_refreshed("movie_info", db.table_version("movie_info"))
    d = det.score_table(db, "movie_info")
    assert d.version_lag == 0 and d.score == 0.0 and d.regret == 0.0


def test_detector_sees_staleness_predating_attach():
    """analyze() stamps the data versions its statistics were taken at,
    so a delta that lands BETWEEN analyze and controller attachment still
    counts as catalog lag — stale-at-attach tables are not invisible."""
    from repro.sql.catalog import analyze
    db = fresh_db(scale=0.05)
    db.stats = analyze(db, rng=np.random.default_rng(3))
    assert db.stats.versions["movie_info"] == 0
    apply_delta(db, DeltaBatch("movie_info", n_append=2000, seed=1))
    det = DriftDetector()
    det.snapshot(db)                     # attach AFTER the delta
    d = det.score_table(db, "movie_info")
    assert d.version_lag == 1 and d.score > 0
    # a re-ANALYZE re-stamps: a fresh snapshot is back in sync
    db.stats = analyze(db, rng=np.random.default_rng(4))
    det2 = DriftDetector()
    det2.snapshot(db)
    assert det2.score_table(db, "movie_info").version_lag == 0


def test_detector_deterministic_across_identical_runs(job_workload, agent):
    """Same seed => identical scores, refresh decisions, refresh times and
    controller counters across two full serving runs."""
    def run():
        db = fresh_db(scale=0.05)
        rb = ReplayBuffer()
        ctl = DriftController(policy=RefreshPolicy("threshold",
                                                   threshold=0.5),
                              replay=rb)
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=2, hooks=[TrajectoryHarvester(rb), ctl])
        stream = drifting_delta_stream(
            [fast_query(i) for i in range(4)], n_queries=10, seed=21,
            drift_table="movie_info", drift_at=4, growth_rows=4000,
            churn_table="movie_keyword", churn_every=3, churn_rows=200)
        comps, _ = svc.run(stream)
        scores = {t: (d.version_lag, d.score)
                  for t, d in ctl.scores().items()}
        summary = ctl.summary()
        for k in ("analyze_wall_s", "host_seconds"):   # host wall time
            summary.pop(k)
        return ([(c.seq, c.finish_t, tuple(c.traj.actions)) for c in comps],
                svc.scheduler.task_log, ctl.refresh_log, scores, summary)

    assert run() == run()


# ------------------------------------------------- never == the PR-4 path
def test_refresh_never_bit_identical_to_no_controller(job_workload, agent):
    """The full control plane attached with RefreshPolicy("never") (and no
    refit/probe actuators) must serve completion-bit-identically to a run
    with no controller at all — detection is free, actuation is opt-in."""
    stream = drifting_delta_stream(
        [fast_query(i) for i in range(4)], n_queries=12, seed=33,
        drift_table="movie_info", drift_at=5, growth_rows=4000)

    def serve(with_controller):
        db = fresh_db(scale=0.05)
        hooks = []
        if with_controller:
            rb = ReplayBuffer()
            hooks = [TrajectoryHarvester(rb),
                     DriftController(policy=RefreshPolicy("never"),
                                     replay=rb)]
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=2, hooks=hooks)
        comps, _ = svc.run(stream)
        return comps, svc

    plain, _ = serve(False)
    gated, svc = serve(True)
    assert svc.scheduler.task_log == []           # never scheduled a task
    assert [c.seq for c in plain] == [c.seq for c in gated]
    assert [c.admit_t for c in plain] == [c.admit_t for c in gated]
    assert [c.finish_t for c in plain] == [c.finish_t for c in gated]
    assert [c.lane for c in plain] == [c.lane for c in gated]
    assert [c.traj.actions for c in plain] == \
        [c.traj.actions for c in gated]
    np.testing.assert_array_equal(
        np.concatenate([c.traj.logps for c in plain]),
        np.concatenate([c.traj.logps for c in gated]))


# ----------------------------------------------------- re-ANALYZE barrier
def test_reanalyze_is_write_barrier_and_catalog_catches_up(job_workload,
                                                           agent):
    """A threshold refresh runs as a write-barrier task: it lands after
    every previously admitted query drains, later queries admit at or
    after it, and the catalog's row counts equal the live table's."""
    db = fresh_db(scale=0.05)
    rb = ReplayBuffer()
    ctl = DriftController(policy=RefreshPolicy("threshold", threshold=0.5),
                          replay=rb)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       hooks=[TrajectoryHarvester(rb), ctl])
    stream = drifting_delta_stream(
        [fast_query(i) for i in range(4)], n_queries=10, seed=5,
        drift_table="movie_info", drift_at=4, growth_rows=5000)
    stale_rows = db.stats.tables["movie_info"].nrows
    comps, _ = svc.run(stream)
    assert len(svc.scheduler.task_log) >= 1
    t_task, label = svc.scheduler.task_log[0]
    assert label.startswith("re-analyze:") and "movie_info" in label
    # barrier semantics on the virtual clock
    before = [c for c in comps if c.admit_t < t_task]
    after = [c for c in comps if c.admit_t >= t_task]
    assert before and after
    assert all(c.finish_t <= t_task for c in before)
    # the catalog caught up: believed rows == live rows != stale snapshot
    live = db.table("movie_info").nrows
    assert db.stats.tables["movie_info"].nrows == live != stale_rows
    assert svc.est.stats.tables["movie_info"].nrows == live
    # the explicit cost charge is recorded (modeled deterministic + wall)
    assert ctl.stats.analyze_modeled_s > 0
    assert ctl.stats.refresh_events >= 1
    assert ctl.stats.tables_refreshed >= 1
    # and the detector no longer flags the refreshed table
    assert ctl.scores()["movie_info"].version_lag == 0


def test_barrier_task_charge_delays_later_admissions(job_workload, agent):
    """A barrier task's returned virtual charge is a foreground
    maintenance window: the task drains in-flight queries, applies at
    their last finish, and queries admitted afterwards are floored by
    apply + charge."""
    def serve(dt):
        db = fresh_db(scale=0.05)
        sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                              n_lanes=1, policy="async")
        ran = []

        def hook(comp):
            if comp.seq == 0:
                sched.schedule_barrier(
                    lambda s, t_apply: ran.append(t_apply) or dt,
                    label="window")
        sched.on_complete.append(hook)
        comps = sched.run([Arrival(0.0, query=fast_query(0), seed=1),
                           Arrival(0.1, query=fast_query(1), seed=2)])
        return comps, sched.task_log, ran

    free, log_free, ran_free = serve(0.0)
    paid, log_paid, ran_paid = serve(5.0)
    # the task applied once q0 drained, at the same instant in both runs
    assert ran_free == ran_paid == [free[0].finish_t]
    assert log_free == [(free[0].finish_t, "window")]
    assert log_paid == [(paid[0].finish_t + 5.0, "window")]
    # q1 (already arrived at t=0.1) waits out the whole charged window
    assert free[1].admit_t == free[0].finish_t
    assert paid[1].admit_t == paid[0].finish_t + 5.0
    assert paid[0].admit_t == free[0].admit_t     # pre-task query untouched


def test_delta_behind_charged_window_does_not_rewind_write_floor(
        job_workload, agent):
    """A delta arriving inside a charged maintenance window applies at
    the window's END: the write floor is monotone, and queries behind the
    delta admit after both barriers."""
    db = fresh_db(scale=0.05)
    sched = LaneScheduler(db, Estimator(db, db.stats), agent, n_lanes=1,
                          policy="async")

    def hook(comp):
        if comp.seq == 0:
            sched.schedule_barrier(lambda s, t: 5.0, label="window")
    sched.on_complete.append(hook)
    comps = sched.run([
        Arrival(0.0, query=fast_query(0), seed=1),
        Arrival(0.1, delta=DeltaBatch("movie_info", n_append=500, seed=2)),
        Arrival(0.2, query=fast_query(1), seed=3)])
    t_window_end = sched.task_log[0][0]
    assert t_window_end == comps[0].finish_t + 5.0
    t_delta = sched.delta_log[0][0]
    assert t_delta >= t_window_end, "delta must not rewind the floor"
    assert comps[1].admit_t >= t_delta


def test_reanalyze_charge_virtual_shifts_barrier_end(job_workload, agent):
    """charge_virtual=True prices the controller's re-ANALYZE onto the
    virtual clock: same refresh decisions and modeled cost, but the
    barrier end (the floor for later admissions) moves out by exactly the
    modeled analyze seconds; no admission ever gets EARLIER."""
    stream = drifting_delta_stream(
        [fast_query(i) for i in range(4)], n_queries=10, seed=5,
        drift_table="movie_info", drift_at=4, growth_rows=5000)

    def serve(charge):
        db = fresh_db(scale=0.05)
        rb = ReplayBuffer()
        ctl = DriftController(policy=RefreshPolicy("threshold",
                                                   threshold=0.5),
                              replay=rb, charge_virtual=charge)
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=2, hooks=[TrajectoryHarvester(rb), ctl])
        comps, _ = svc.run(stream)
        return comps, svc.scheduler.task_log, ctl

    free, log_free, ctl_free = serve(False)
    paid, log_paid, ctl_paid = serve(True)
    assert len(log_free) == len(log_paid) >= 1
    assert ctl_paid.stats.analyze_modeled_s == ctl_free.stats.analyze_modeled_s
    dt = ctl_paid.stats.analyze_modeled_s       # unrounded, single event
    assert dt > 0
    assert log_paid[0][0] == pytest.approx(log_free[0][0] + dt)
    for a, b in zip(free, paid):
        assert b.admit_t >= a.admit_t - 1e-12   # charging never speeds up


def test_delta_triggered_refresh_lands_at_the_same_barrier(job_workload,
                                                           agent):
    """Catalog lag is born at the delta — and the delta barrier already
    drained every lane. The controller decides there (on_delta), so the
    re-ANALYZE task applies at the delta's own apply time: no extra drain
    stall, and the FIRST post-delta query already plans on fresh stats."""
    db = fresh_db(scale=0.05)
    rb = ReplayBuffer()
    ctl = DriftController(policy=RefreshPolicy("always"), replay=rb)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       hooks=[TrajectoryHarvester(rb), ctl])
    stream = drifting_delta_stream(
        [fast_query(i) for i in range(4)], n_queries=8, seed=11,
        drift_table="movie_info", drift_at=3, growth_rows=3000)
    comps, _ = svc.run(stream)
    sched = svc.scheduler
    assert len(sched.delta_log) == 1 and len(sched.task_log) == 1
    t_delta = sched.delta_log[0][0]
    t_task, label = sched.task_log[0]
    assert t_task == t_delta and "movie_info" in label
    # the first post-delta admission already saw refreshed stats: the
    # catalog never lagged for any planned query
    assert db.stats.tables["movie_info"].nrows == \
        db.table("movie_info").nrows
    assert ctl.scores()["movie_info"].version_lag == 0


def test_reanalyze_leaves_stage_cache_correct(job_workload, agent):
    """Re-ANALYZE changes the catalog, not the data: resident stage-cache
    entries stay VALID (no version bump), and post-refresh executions are
    still bit-for-bit identical to cache-off runs."""
    db = fresh_db(scale=0.06)
    est = Estimator(db, db.stats)
    q = mi_join_query("q_reanalyze")
    r1 = run_adaptive(db, q, syntactic_plan(q), est)
    n_entries = len(db._stage_cache)
    assert n_entries > 0
    # incremental re-ANALYZE of every table the query touches
    for t in ("title", "movie_info", "info_type"):
        db.stats.tables[t] = analyze_table(db, t,
                                           rng=np.random.default_rng(4))
    assert len(db._stage_cache) == n_entries     # nothing was dropped
    assert db._stage_cache.stats.invalidations == 0
    r2 = run_adaptive(db, q, syntactic_plan(q), est)        # warm
    ref = run_adaptive(db, q, syntactic_plan(q), est, reuse_stages=False)
    assert r2.latency == ref.latency == r1.latency
    assert [s.out_rows for s in r2.stages] == \
        [s.out_rows for s in ref.stages]
    assert db._stage_cache.stats.hits > 0        # the entries were reused


def test_reanalyze_fences_policy_store_incumbent_cache(job_workload, agent,
                                                       tmp_path):
    """Fresh statistics change probe planning WITHOUT a data-version bump:
    the store's version-keyed incumbent score must be dropped by the
    refresh (note_stats_refresh), and by probe-set swaps (set_probe)."""
    store = PolicyStore(tmp_path / "ps", [fast_query(0)])
    store._inc_score = (("sentinel",), 1.23)
    store.note_stats_refresh()
    assert store._inc_score is None
    store._inc_score = (("sentinel",), 1.23)
    store.set_probe([fast_query(1)], reason="coverage")
    assert store._inc_score is None
    assert store.probe_log[-1]["reason"] == "coverage"
    # end-to-end: a controller-run refresh fences an attached store
    db = fresh_db(scale=0.05)
    rb = ReplayBuffer()
    store2 = PolicyStore(tmp_path / "ps2", [fast_query(0)])
    store2._inc_score = (("sentinel",), 9.9)
    ctl = DriftController(policy=RefreshPolicy("always"), replay=rb,
                          store=store2)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       hooks=[TrajectoryHarvester(rb), ctl])
    svc.run(drifting_delta_stream([fast_query(i) for i in range(3)],
                                  n_queries=6, seed=3,
                                  drift_table="movie_info", drift_at=2,
                                  growth_rows=3000))
    assert ctl.stats.refresh_events >= 1
    assert store2._inc_score is None


# --------------------------------------------------------- refresh policy
def test_refresh_policy_kinds():
    cost = lambda t: 1.0
    drifts = {"a": _drift("a", 3.0), "b": _drift("b", 0.4),
              "c": TableDrift("c", 0, 1.0, 0.0, 0.0, 0.0)}   # no lag
    assert RefreshPolicy("never").decide(drifts, 0.0, cost).tables == ()
    # always: every table with version lag, regardless of score
    assert RefreshPolicy("always").decide(drifts, 0.0, cost).tables == \
        ("a", "b")
    # threshold: only the hot table
    assert RefreshPolicy("threshold", threshold=1.0).decide(
        drifts, 0.0, cost).tables == ("a",)
    with pytest.raises(AssertionError):
        RefreshPolicy("bogus")
    with pytest.raises(AssertionError):
        RefreshPolicy("budgeted")                 # budget_s required


def test_refresh_policy_budget_and_cooldown():
    drifts = {"a": _drift("a", 3.0), "b": _drift("b", 2.0),
              "d": _drift("d", 1.5)}
    pol = RefreshPolicy("budgeted", threshold=1.0, budget_s=2.5)
    dec = pol.decide(drifts, 0.0, lambda t: 1.0)
    # highest score first, stop when the NEXT table would bust the budget
    assert dec.tables == ("a", "b") and dec.modeled_cost_s == 2.0
    # the budget is RESERVED at decision time: a second decision taken
    # while the first task is still queued must not overshoot the ceiling
    assert pol.spent_modeled_s == 2.0
    # only 0.5s of budget left: nothing fits — even before note_refreshed
    assert pol.decide(drifts, 1.0, lambda t: 1.0).tables == ()
    for t in dec.tables:
        pol.note_refreshed(t, 0.0)
    assert pol.spent_modeled_s == 2.0             # no double charge
    # a cheaper lower-score table still fits a partial budget
    pol2 = RefreshPolicy("budgeted", threshold=1.0, budget_s=1.2)
    dec2 = pol2.decide(drifts, 0.0, lambda t: 1.0 if t == "a" else 0.2)
    assert dec2.tables == ("a", "b")              # 1.0 + 0.2 <= 1.2
    # min_interval floors per-table refresh frequency
    pol3 = RefreshPolicy("always", min_interval=10.0)
    assert pol3.decide(drifts, 0.0, lambda t: 0.0).tables == \
        ("a", "b", "d")
    pol3.note_refreshed("a", 0.0)
    assert pol3.decide(drifts, 5.0, lambda t: 0.0).tables == ("b", "d")
    assert "a" in pol3.decide(drifts, 10.0, lambda t: 0.0).tables


def test_incremental_analyze_matches_full_analyze_shape():
    """analyze() is now a fold over analyze_table(): same tables, same
    nrows (exact), deterministic given the rng seed."""
    db = fresh_db(scale=0.05)
    apply_delta(db, DeltaBatch("movie_info", n_append=1000, seed=1))
    full = analyze(db, rng=np.random.default_rng(7))
    assert set(full.tables) == set(db.tables)
    for name, ts in full.tables.items():
        assert ts.nrows == db.table(name).nrows
    one_a = analyze_table(db, "movie_info", rng=np.random.default_rng(9))
    one_b = analyze_table(db, "movie_info", rng=np.random.default_rng(9))
    assert one_a == one_b                          # seeded => deterministic
    assert one_a.nrows == db.table("movie_info").nrows
    assert set(one_a.columns) == set(db.table("movie_info").columns)


# -------------------------------------------------------- probe coverage
def test_coverage_probes_shift_toward_drifted_tables():
    # pool: 8 cast_info-touching traps + 8 title-only dimension joins
    from scenarios import trap_query
    pool = [trap_query(i, 1940 + i) for i in range(8)] + \
        [fast_query(i) for i in range(8)]
    cover = CoverageProbeSet(pool, k=6, seed=11)
    flat = cover.resample({})                      # no drift: uniform draw

    drifts = {"cast_info": _drift("cast_info", 8.0)}
    hot = cover.resample(drifts)
    touches = lambda qs: sum("cast_info" in {r.table for r in q.relations}
                             for q in qs)
    assert touches(hot) > touches(flat)
    assert touches(hot) >= 5                       # near-total coverage
    # weights: every pool entry keeps base mass (undrifted stay gateable)
    w = cover.weights(drifts)
    assert (w > 0).all() and w.max() > 10 * w.min()
    # deterministic: same seed, same call sequence => same sets
    cover2 = CoverageProbeSet(pool, k=6, seed=11)
    assert [q.name for q in cover2.resample({})] == \
        [q.name for q in flat]
    assert [q.name for q in cover2.resample(drifts)] == \
        [q.name for q in hot]


def test_controller_installs_coverage_probes(job_workload, agent, tmp_path):
    """When a table crosses probe_threshold the controller re-samples the
    gate's probe set toward it — once per drifted-table set, not per
    completion."""
    from scenarios import trap_query
    pool = [trap_query(i, 1940 + i) for i in range(6)] + \
        [fast_query(i) for i in range(6)]
    db = fresh_db(scale=0.05)
    rb = ReplayBuffer()
    store = PolicyStore(tmp_path / "ps", [fast_query(0), fast_query(1)])
    fixed = [q.name for q in store.probe]
    ctl = DriftController(policy=RefreshPolicy("never"), replay=rb,
                          store=store,
                          probes=CoverageProbeSet(pool, k=4, seed=2),
                          probe_threshold=0.5)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       hooks=[TrajectoryHarvester(rb), ctl])
    svc.run(drifting_delta_stream([fast_query(i) for i in range(3)],
                                  n_queries=8, seed=13,
                                  drift_table="cast_info", drift_at=3,
                                  growth_rows=20000))
    assert ctl.stats.probe_resamples == 1          # one set change, one swap
    assert [q.name for q in store.probe] != fixed
    assert sum("cast_info" in {r.table for r in q.relations}
               for q in store.probe) >= 2


# ------------------------------------------------------- refit generation
def test_refit_on_drift_generation_fencing(job_workload, agent):
    """refit_on_drift retrains from the live replay buffer, bumps the fit
    generation and drops the per-query memo — admissions after the refit
    see the new model, never a stale memoized estimate."""
    from repro.learn.replay import Experience
    from repro.core.rollout import Trajectory
    pred = LatencyPredictor(agent.meta, seed=3, lr=5e-3)
    strag = straggler_query()
    enc = encode_query(strag, agent.meta)
    rb = ReplayBuffer()
    for i in range(16):
        t = Trajectory()
        t.actions, t.states = [0], [enc]
        rb.add(Experience(seq=i, query_name="straggler", traj=t,
                          latency=300.0, failed=True, finish_t=float(i),
                          tables=("cast_info",), versions={"cast_info": 1}))
    before = pred.predict_query(strag)
    gen0 = pred.generation
    assert pred._pred_memo                          # memoized
    loss = pred.refit_on_drift(rb, np.random.default_rng(0),
                               current_versions={"cast_info": 1},
                               trigger="test")
    assert pred.generation > gen0 and pred.n_refits == 1
    assert not pred._pred_memo                      # memo fenced
    assert pred.refit_log[-1]["trigger"] == "test"
    for _ in range(11):
        pred.refit_on_drift(rb, np.random.default_rng(0))
    after = pred.predict_query(strag)
    assert after != before
    assert after > 100.0, f"refit should pull toward 300s, got {after}"
    assert np.isfinite(loss)
    # reset_stats: memos drop, generation/counters do NOT rewind
    pred.predict_query(strag)
    gen = pred.generation
    pred.reset_stats()
    assert not pred._pred_memo and not pred._enc_memo
    assert pred.generation == gen and pred.n_refits == 12


def test_controller_refit_trigger_and_cooldown(job_workload, agent):
    """The controller refits only once drift crosses refit_threshold, at
    most once per refit_every completions, with deterministic triggers."""
    def run():
        db = fresh_db(scale=0.05)
        rb = ReplayBuffer()
        pred = LatencyPredictor(agent.meta, seed=1)
        ctl = DriftController(policy=RefreshPolicy("never"), replay=rb,
                              predictor=pred, refit_threshold=0.5,
                              refit_every=4, refit_samples=8)
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=2, hooks=[TrajectoryHarvester(rb), ctl])
        svc.run(drifting_delta_stream([fast_query(i) for i in range(4)],
                                      n_queries=12, seed=7,
                                      drift_table="movie_info", drift_at=4,
                                      growth_rows=4000))
        return ctl, pred

    ctl, pred = run()
    assert ctl.stats.refits >= 1
    assert pred.n_refits == ctl.stats.refits
    # cooldown: at most one refit per refit_every completions
    assert ctl.stats.refits <= ctl.stats.completions // 4
    ctl2, pred2 = run()
    assert [r["trigger"] for r in pred.refit_log] == \
        [r["trigger"] for r in pred2.refit_log]


# ------------------------------------------- curriculum demotion actuator
def test_curriculum_note_drift_floor_and_cooldown():
    """`note_drift` semantics in isolation: threshold-gated, one demotion
    per cooldown window, floored at stage 1, window/dwell reset."""
    from types import SimpleNamespace

    from repro.learn import AdaptiveCurriculum

    cur = AdaptiveCurriculum(start_stage=3, window=4, min_dwell=4,
                             drift_demote_threshold=0.4, drift_cooldown=3)
    comp = SimpleNamespace(result=SimpleNamespace(failed=False, latency=1.0))
    assert not cur.note_drift(0.39) and cur.stage == 3   # below threshold
    assert cur.note_drift(0.41) and cur.stage == 2
    assert cur.drift_demotions == [0] and cur.demotions == [0]
    assert len(cur._window) == 0                         # track record reset
    assert not cur.note_drift(0.9) and cur.stage == 2    # cooldown holds
    for _ in range(3):
        cur.observe(comp)
    assert cur.note_drift(0.9) and cur.stage == 1        # cooldown elapsed
    assert not cur.note_drift(0.9) and cur.stage == 1    # floored at 1
    assert cur.stats()["drift_demotions"] == [0, 3]


def test_controller_demotes_curriculum_on_attributed_drift(job_workload,
                                                           agent):
    """The fourth actuator: a growth delta raises the detector's peak
    score past `drift_demote_threshold`, and the shared curriculum drops
    a stage — PROACTIVELY, while the success-rate window is still clean
    (every completion here succeeds). Deterministic across runs."""
    from repro.learn import AdaptiveCurriculum

    class CurriculumWire:           # what BackgroundLearner does in prod
        def __init__(self, cur):
            self.cur = cur

        def attach(self, sched):
            sched.on_complete.append(self.cur.observe)

    def run(with_curriculum):
        db = fresh_db(scale=0.05)
        cur = AdaptiveCurriculum(start_stage=3, drift_demote_threshold=0.3) \
            if with_curriculum else None
        ctl = DriftController(policy=RefreshPolicy("never"), curriculum=cur)
        hooks = ([CurriculumWire(cur)] if cur else []) + [ctl]
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=2, hooks=hooks)
        comps, _ = svc.run(drifting_delta_stream(
            [fast_query(i) for i in range(4)], n_queries=12, seed=11,
            drift_table="movie_info", drift_at=4, growth_rows=6000))
        return comps, ctl, cur

    comps, ctl, cur = run(True)
    assert all(not c.result.failed for c in comps)       # success governor
    assert cur.stage == 2                                #   never fired...
    assert ctl.stats.curriculum_demotions == 1           #   ...this did
    assert cur.drift_demotions and cur.demotions == cur.drift_demotions
    # demotion lands only after the growth delta's completions
    assert cur.drift_demotions[0] > 4
    # bit-deterministic: same stream, same demotion point
    _, ctl2, cur2 = run(True)
    assert cur2.drift_demotions == cur.drift_demotions
    assert ctl2.stats.curriculum_demotions == 1
    # no curriculum => the actuator (and its counter) stays off
    _, ctl0, _ = run(False)
    assert ctl0.stats.curriculum_demotions == 0
