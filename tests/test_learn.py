"""Lifelong-learning loop invariants: prioritized replay math, adaptive
curriculum promotion, harvested trajectories == offline rollouts (same
PPO gradients), bit-reproducible serving with the learner ON, and the
policy-store gate (corrupted candidate rejected, serving continues on the
prior version; shadow mode never swaps; rollback restores).

Scenario builders (fresh dbs, seeded agents) live in tests/scenarios.py."""
import numpy as np
import pytest

import jax

from scenarios import fresh_db, make_agent

from repro.checkpoint import (agent_state, copy_tree, install_agent_state,
                              params_finite)
from repro.core.rollout import Trajectory, rollout
from repro.learn import (AdaptiveCurriculum, Experience, PolicyStore,
                         ReplayBuffer, TrajectoryHarvester, make_online_loop)
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.serve.service import QueryService
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel


def _exp(seq, name, latency, versions, tables=("title",), failed=False):
    t = Trajectory()
    t.actions = [0]
    return Experience(seq=seq, query_name=name, traj=t, latency=latency,
                      failed=failed, finish_t=float(seq), tables=tables,
                      versions=dict(versions))


# -------------------------------------------------------------- replay
def test_replay_priorities_fresh_regret_and_failure_dominate():
    rb = ReplayBuffer(capacity=8, recency_decay=1.0, fresh_boost=4.0,
                      regret_scale=1.0, fail_boost=2.0)
    rb.add(_exp(0, "q", 1.0, {"title": 0}))        # stale after the delta
    rb.add(_exp(1, "q", 1.0, {"title": 1}))        # fresh, zero regret
    rb.add(_exp(2, "q", 3.0, {"title": 1}))        # fresh, 2x regret
    rb.add(_exp(3, "q", 3.0, {"title": 1}, failed=True))
    p = rb.priorities({"title": 1})
    assert p[1] > p[0]                 # freshness beats stale
    assert p[2] > p[1]                 # regret adds weight
    assert p[3] > p[2]                 # failure boosts further
    assert p[1] == pytest.approx(4.0) and p[2] == pytest.approx(12.0)
    assert p[0] == pytest.approx(1.0) and p[3] == pytest.approx(24.0)


def test_replay_recency_decay_and_eviction():
    rb = ReplayBuffer(capacity=3, recency_decay=0.5, fresh_boost=1.0,
                      regret_scale=0.0)
    for i in range(5):
        rb.add(_exp(i, f"q{i}", 1.0, {}))
    assert len(rb) == 3 and rb.n_evicted == 2
    assert [e.seq for e in rb.all()] == [2, 3, 4]
    p = rb.priorities({})
    assert p[0] == pytest.approx(0.25) and p[2] == pytest.approx(1.0)


def test_replay_sampling_is_deterministic():
    rb = ReplayBuffer(capacity=16)
    for i in range(10):
        rb.add(_exp(i, f"q{i % 3}", 1.0 + i, {"title": i % 2}))
    a = rb.sample(4, np.random.default_rng(7), {"title": 1})
    b = rb.sample(4, np.random.default_rng(7), {"title": 1})
    assert [e.seq for e in a] == [e.seq for e in b]
    assert len(a) == 4
    assert rb.sample(99, np.random.default_rng(0), {})  # clamps to size


# ---------------------------------------------------------- curriculum
class _FakeComp:
    def __init__(self, failed, latency):
        self.result = type("R", (), {"failed": failed, "latency": latency})()


def test_adaptive_curriculum_promotes_on_success_window():
    cur = AdaptiveCurriculum(window=4, promote_success=0.75, min_dwell=4)
    assert cur.stage == 1
    for _ in range(3):
        cur.observe(_FakeComp(False, 1.0))
    assert cur.stage == 1              # window not yet full
    cur.observe(_FakeComp(False, 1.0))
    assert cur.stage == 2 and cur.promotions == [4]
    # failures hold the next promotion back
    for _ in range(8):
        cur.observe(_FakeComp(True, 1.0))
    assert cur.stage == 2
    for _ in range(4):
        cur.observe(_FakeComp(False, 1.0))
    assert cur.stage == 3
    for _ in range(8):                 # stage 3 is terminal
        cur.observe(_FakeComp(False, 1.0))
    assert cur.stage == 3


def test_adaptive_curriculum_demotes_on_failure_spike():
    cur = AdaptiveCurriculum(window=4, promote_success=0.75, min_dwell=4,
                             demote_success=0.5)
    for _ in range(8):
        cur.observe(_FakeComp(False, 1.0))
    assert cur.stage == 3 and cur.promotions == [4, 8]
    # drift hits: 3 of 4 in the window fail -> demote one stage
    for _ in range(2):
        cur.observe(_FakeComp(False, 1.0))
    for _ in range(3):
        cur.observe(_FakeComp(True, 1.0))
    assert cur.stage == 2 and cur.demotions == [13]
    # keeps failing -> demote to 1, never below
    for _ in range(8):
        cur.observe(_FakeComp(True, 1.0))
    assert cur.stage == 1
    for _ in range(8):
        cur.observe(_FakeComp(True, 1.0))
    assert cur.stage == 1
    # recovery re-earns the stages
    for _ in range(8):
        cur.observe(_FakeComp(False, 1.0))
    assert cur.stage == 3


def test_adaptive_curriculum_latency_ceiling():
    cur = AdaptiveCurriculum(window=2, promote_success=0.5, min_dwell=2,
                             promote_p50=1.0)
    for _ in range(6):
        cur.observe(_FakeComp(False, 5.0))
    assert cur.stage == 1              # succeeds but too slow
    for _ in range(2):
        cur.observe(_FakeComp(False, 0.5))
    assert cur.stage == 2


# ------------------------------------------------- harvest == offline
def test_harvested_trajectories_match_offline_gradients(job_workload):
    """Acceptance (c): trajectories captured from the serving scheduler,
    replayed through ppo_update_batch, produce the same params as an
    offline agent updated on serial rollouts of the same episodes."""
    db = fresh_db(scale=0.05)
    est = Estimator(db, db.stats)
    serve_agent = make_agent(job_workload, seed=11)
    offline_agent = make_agent(job_workload, seed=11)

    qs = job_workload.test[:5]
    seeds = [101, 102, 103, 104, 105]
    harv = TrajectoryHarvester()
    sched = LaneScheduler(db, est, serve_agent, n_lanes=2, explore=True,
                          policy="async")
    harv.attach(sched)
    sched.run([Arrival(0.4 * i, query=q, seed=s)
               for i, (q, s) in enumerate(zip(qs, seeds))])
    assert harv.n_seen == 5
    exps = harv.replay.all()
    assert [e.seq for e in exps] == sorted(e.seq for e in exps)

    offline = [rollout(db, q, est, offline_agent, stage=3, explore=True,
                       key=s) for q, s in zip(qs, seeds)]
    for e, t in zip(exps, [t for t in offline if t.actions]):
        assert e.traj.actions == t.actions and e.traj.rewards == t.rewards

    serve_agent.ppo_update_batch([e.traj for e in exps])
    offline_agent.ppo_update_batch(offline)
    for a, b in zip(jax.tree_util.tree_leaves(agent_state(serve_agent)),
                    jax.tree_util.tree_leaves(agent_state(offline_agent))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


# ------------------------------------------------------- reproducibility
def test_online_serving_bit_reproducible_with_learner_on(job_workload,
                                                         tmp_path):
    """Acceptance (a): same seed => bit-identical completions, updates,
    swaps and curriculum promotions with the learner running."""
    def run(tag):
        db = fresh_db(scale=0.05)
        est = Estimator(db, db.stats)
        agent = make_agent(job_workload, seed=0)
        store = PolicyStore(tmp_path / f"ps_{tag}", job_workload.test[:2])
        h, l = make_online_loop(
            agent, store=store, update_every=3, sample_size=3,
            gate_every=2, seed=5,
            curriculum=AdaptiveCurriculum(window=4, min_dwell=4))
        svc = QueryService(db, agent, est=est, n_lanes=2, policy="async",
                           explore=True, hooks=[h, l])
        qs = job_workload.train[:6]
        rng = np.random.default_rng(9)
        stream = [Arrival(0.5 * i, query=qs[i % len(qs)],
                          seed=int(rng.integers(2 ** 31)))
                  for i in range(12)]
        comps, _ = svc.run(stream)
        return comps, l

    c1, l1 = run("a")
    c2, l2 = run("b")
    assert [c.traj.actions for c in c1] == [c.traj.actions for c in c2]
    assert [c.finish_t for c in c1] == [c.finish_t for c in c2]
    assert [c.result.latency for c in c1] == [c.result.latency for c in c2]
    np.testing.assert_array_equal(
        np.concatenate([c.traj.logps for c in c1]),
        np.concatenate([c.traj.logps for c in c2]))
    s1, s2 = l1.stats.as_dict(), l2.stats.as_dict()
    s1.pop("host_seconds"), s2.pop("host_seconds")
    assert s1 == s2
    assert l1.curriculum.promotions == l2.curriculum.promotions
    assert [g["accepted"] for g in l1.store.gate_log] == \
        [g["accepted"] for g in l2.store.gate_log]


# ------------------------------------------------------------- the gate
def _nan_corrupt(agent):
    agent.actor = jax.tree_util.tree_map(lambda x: x * np.nan, agent.actor)


def test_gate_rejects_corrupted_candidate_and_serving_continues(
        job_workload, tmp_path):
    """Acceptance (b): a corrupted candidate never swaps in; the serving
    agent keeps its prior params and keeps serving."""
    db = fresh_db(scale=0.05)
    est = Estimator(db, db.stats)
    cluster = ClusterModel()
    serving = make_agent(job_workload, seed=0)
    store = PolicyStore(tmp_path / "ps", job_workload.test[:2])
    store.commit(serving, step=0)

    cand = make_agent(job_workload, seed=1)
    install_agent_state(cand, agent_state(serving))
    _nan_corrupt(cand)
    assert not params_finite(cand)
    before = copy_tree(agent_state(serving))

    rec = store.evaluate_and_maybe_swap(serving, cand, db=db, est=est,
                                        cluster=cluster, step=1)
    assert not rec["accepted"] and "non-finite" in rec["reason"]
    assert store.serving_step == 0 and len(store.versions) == 1
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(agent_state(serving))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # serving continues on the prior version
    traj = rollout(db, job_workload.test[0], est, serving, stage=3,
                   explore=False, cluster=cluster)
    assert np.isfinite(traj.result.latency)


def test_gate_accepts_equal_candidate_and_shadow_never_swaps(
        job_workload, tmp_path):
    db = fresh_db(scale=0.05)
    est = Estimator(db, db.stats)
    cluster = ClusterModel()
    serving = make_agent(job_workload, seed=0)
    cand = make_agent(job_workload, seed=1)
    install_agent_state(cand, agent_state(serving))

    shadow = PolicyStore(tmp_path / "shadow", job_workload.test[:2],
                         mode="shadow")
    rec = shadow.evaluate_and_maybe_swap(serving, cand, db=db, est=est,
                                         cluster=cluster, step=1)
    assert rec["accepted"] and not rec["swapped"] and not shadow.versions

    gate = PolicyStore(tmp_path / "gate", job_workload.test[:2])
    rec = gate.evaluate_and_maybe_swap(serving, cand, db=db, est=est,
                                       cluster=cluster, step=1)
    assert rec["accepted"] and rec["swapped"]
    assert gate.serving_step == 1 and len(gate.versions) == 1


def test_policy_store_rollback_restores_committed_version(job_workload,
                                                          tmp_path):
    db = fresh_db(scale=0.05)
    agent = make_agent(job_workload, seed=0)
    store = PolicyStore(tmp_path / "ps", [])
    store.commit(agent, step=0)
    committed = copy_tree(agent_state(agent))
    _nan_corrupt(agent)
    assert not params_finite(agent)
    assert store.rollback(agent) == 0
    assert params_finite(agent)
    for a, b in zip(jax.tree_util.tree_leaves(committed),
                    jax.tree_util.tree_leaves(agent_state(agent))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- harvester
def test_harvester_skips_empty_trajectories():
    rb = ReplayBuffer()
    h = TrajectoryHarvester(rb)

    class _Sched:
        db = type("D", (), {"table_version": staticmethod(lambda n: 0)})()
        on_complete = []
    h.attach(_Sched())

    class _Rel:
        table = "title"

    class _Q:
        name = "q0"
        relations = (_Rel(),)
    traj = Trajectory()
    res = type("R", (), {"latency": 1.0, "failed": False})()
    comp = type("C", (), {"seq": 0, "query": _Q(), "traj": traj,
                          "result": res, "finish_t": 1.0})()
    h._on_complete(comp)
    assert h.n_empty == 1 and len(rb) == 0
    traj.actions = [1]
    h._on_complete(comp)
    assert h.n_harvested == 1 and len(rb) == 1
    assert rb.all()[0].tables == ("title",)
