"""QoS control-plane invariants: deterministic admissions/degradations on
the virtual clock, tenant isolation (a flooding tenant can neither evict
another tenant's cache entries nor starve its lanes), EDF ordering, the
degradation ladder / token bucket units, predictor warm-start semantics,
and the predictor-off fallback being bit-identical to plain async.

Scenario builders (fresh dbs, fast/straggler queries, the two-tenant QoS
setup + stream, the FixedPredictor stub) live in tests/scenarios.py; the
`agent` fixture is the session-scoped one from conftest.py.
"""
import pytest

from scenarios import (FixedPredictor, fast_query, fast_subset, fresh_db,
                       qos_setup, qos_stream, straggler_query)

from repro.serve.cache import PartitionedStageCache
from repro.serve.driver import TenantTraffic, multi_tenant_stream
from repro.serve.qos import (AdmissionPolicy, DegradationLadder,
                             LatencyPredictor, QoSAdmission, TenantRegistry,
                             TenantSpec, encode_query)
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.serve.service import QueryService
from repro.sql.cbo import Estimator


# ------------------------------------------------------------------ units
def test_token_bucket_on_virtual_clock():
    reg = TenantRegistry([TenantSpec("t", rate=1.0, burst=2)])
    # probing is pure: repeated calls at the same time agree
    assert reg.earliest_admit("t", 0.0) == 0.0
    assert reg.earliest_admit("t", 0.0) == 0.0
    reg.acquire("t", 0.0)
    reg.acquire("t", 0.0)                       # burst of 2 consumed
    t_next = reg.earliest_admit("t", 0.0)
    assert t_next == pytest.approx(1.0)         # 1 token / virtual second
    assert reg.earliest_admit("t", 0.0) == pytest.approx(1.0)
    reg.acquire("t", t_next)
    assert reg.earliest_admit("t", t_next) == pytest.approx(t_next + 1.0)
    # unknown tenants are unlimited
    assert reg.earliest_admit("other", 5.0) == 5.0
    # a fresh run restarts the bucket clock (one admission object can
    # serve several streams reproducibly)
    reg.reset_clock()
    assert reg.earliest_admit("t", 0.0) == 0.0
    # degenerate specs are rejected at registration, not mid-run
    with pytest.raises(AssertionError):
        TenantRegistry([TenantSpec("bad", rate=1.0, burst=0)])
    with pytest.raises(AssertionError):
        TenantRegistry([TenantSpec("bad", rate=0.0)])


def test_partitioned_cache_default_tenant_budget():
    """An explicit budget for the 'default' tenant sizes the base cache
    itself (partition('default') IS the object); UNBUDGETED tenant ids
    share the default partition, so a stream of distinct ids cannot grow
    memory past sum(budgets) + default."""
    c = PartitionedStageCache(default_bytes=1 << 20,
                              budgets={"default": 100, "t": 200})
    assert c.partition("default") is c and c.max_bytes == 100
    assert c.partition("t").max_bytes == 200
    assert c.partition("other") is c
    assert c.partition("another") is c and not c._parts.keys() - {"t"}


def test_degradation_ladder_rungs():
    lad = DegradationLadder()                   # (1, full) (2, 1) (4, 0)
    assert lad.choose(10.0, 20.0).hook_budget is None        # on track
    assert not lad.choose(10.0, 20.0).degraded
    d = lad.choose(30.0, 20.0)                  # severity 1.5
    assert d.action == "admit" and d.hook_budget == 1 and d.degraded
    d = lad.choose(50.0, 20.0)                  # severity 2.5
    assert d.action == "admit" and d.hook_budget == 0
    assert lad.choose(100.0, 20.0).action == "reject"        # severity 5
    assert lad.choose(1.0, 0.0).action == "reject"           # no slack
    # no reject rung configured: the bottom budget catches everything
    soft = DegradationLadder(reject_above=None)
    d = soft.choose(100.0, 20.0)
    assert d.action == "admit" and d.hook_budget == 0 and d.degraded
    # a reject threshold the rungs would shadow is a config error
    with pytest.raises(AssertionError):
        DegradationLadder(rungs=((1.0, None), (4.0, 0)), reject_above=2.0)


def test_predictor_warm_start_matches_critic(job_workload, agent):
    """Warm-started predictor params ARE the critic: its latency estimate
    must equal max(0, -v)^2 at the same encoded state."""
    pred = LatencyPredictor(agent.meta, agent=agent)
    enc = encode_query(job_workload.test[0], agent.meta)
    v = agent.value(enc)
    assert pred.predict_enc(enc) == pytest.approx(max(0.0, -v) ** 2,
                                                  rel=1e-5)


def test_predictor_fit_separates_slow_from_fast(job_workload, agent):
    pred = LatencyPredictor(agent.meta, seed=3, lr=5e-3)
    fast_enc = encode_query(job_workload.test[0], agent.meta)
    slow_enc = encode_query(straggler_query(), agent.meta)
    encs = [fast_enc, slow_enc] * 8
    lats = [1.0, 300.0] * 8
    first = pred.fit(encs, lats, batch_size=8, epochs=1)
    for _ in range(12):
        last = pred.fit(encs, lats, batch_size=8, epochs=2)
    assert last < first
    p_fast, p_slow = pred.predict_enc(fast_enc), pred.predict_enc(slow_enc)
    assert p_slow > 10 * p_fast, (p_fast, p_slow)
    # the memo is fenced by fit generation: query-level predictions move
    q = job_workload.test[0]
    a = pred.predict_query(q)
    pred.fit([fast_enc], [200.0], batch_size=4, epochs=4)
    assert pred.predict_query(q) != a


# ----------------------------------------------------------- determinism
def test_qos_same_seed_identical_admissions(job_workload, agent):
    """Same seed => identical admissions, degradations, rejections and
    completion times on the virtual clock, including token-bucket
    deferrals and per-tenant cache partitions."""
    runs = []
    for _ in range(2):
        db = fresh_db()
        reg, adm = qos_setup()
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=2, policy="edf", tenants=reg,
                           admission=adm)
        comps, stats = svc.run(qos_stream(job_workload))
        d = stats.as_dict()
        d.pop("hook_seconds")           # host wall time: not virtual-clock
        runs.append((
            [(c.seq, c.tenant, c.admit_t, c.finish_t, c.hook_budget,
              c.degraded, tuple(c.traj.actions)) for c in comps],
            [(r.seq, r.reject_t, r.reason) for r in svc.scheduler.rejections],
            adm.stats(), d))
    assert runs[0] == runs[1]
    comp_rows, reject_rows, adm_stats, _ = runs[0]
    assert len(reject_rows) == 1               # the monster was rejected
    assert adm_stats["deferred"] > 0           # bulk hit its rate limit


def test_qos_admission_reusable_across_runs(job_workload, agent):
    """One admission object serving two streams: the second run must not
    inherit the first run's token-bucket end time (prepare resets the
    virtual-clock-relative state)."""
    db = fresh_db()
    reg, adm = qos_setup()
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       policy="edf", tenants=reg, admission=adm)
    rows = []
    for _ in range(2):
        comps, _ = svc.run(qos_stream(job_workload))
        rows.append([(c.seq, c.admit_t, c.hook_budget) for c in comps])
    assert rows[0] == rows[1]


# ------------------------------------------------------------- isolation
def test_flood_cannot_evict_other_tenants_cache(job_workload, agent):
    victims = [fast_query(i) for i in range(3)]
    floods = [fast_query(100 + i) for i in range(24)]

    # solo pass: learn the victim's working-set signatures
    db = fresh_db()
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2)
    svc.run_queries(victims * 2, seeds=range(6))
    sigs = list(svc.cache._entries.keys())
    ws = svc.cache.bytes
    assert sigs and ws > 0

    reg = TenantRegistry([TenantSpec("victim", cache_bytes=2 * ws),
                          TenantSpec("flood", cache_bytes=ws // 2)])
    db = fresh_db()
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2,
                       tenants=reg)
    stream = multi_tenant_stream([
        TenantTraffic("victim", victims, rate=4.0, n_queries=8, seed=3),
        TenantTraffic("flood", floods, rate=4.0, n_queries=24, seed=4)])
    _, stats = svc.run(stream)
    parts = svc.cache.partitions()
    # zero cross-tenant evictions BY CONSTRUCTION: the victim's partition
    # never evicted although the flood churned its own partition hard
    assert parts["victim"].stats.evictions == 0
    assert parts["flood"].stats.evictions > 0
    assert all(s in parts["victim"] for s in sigs)
    assert stats.per_tenant["victim"].cache["evictions"] == 0
    # the aggregate counters still add up
    agg = svc.cache.aggregate_stats()
    by_tenant = svc.cache.stats_by_tenant()
    assert agg["evictions"] == sum(d["evictions"]
                                   for d in by_tenant.values())
    # reset_stats reaches every partition (counters only: entries stay)
    svc.reset_stats()
    assert all(d["hits"] == 0 and d["misses"] == 0 and d["evictions"] == 0
               for d in svc.cache.stats_by_tenant().values())
    assert all(s in parts["victim"] for s in sigs)


def test_partition_invalidation_is_shared(job_workload, agent):
    """One delta fences EVERY tenant's stale entries (shared version tags):
    post-delta executions are correct in all partitions."""
    from repro.serve.deltas import DeltaBatch, apply_delta
    from repro.sql.executor import AdaptiveRun, run_adaptive
    from repro.sql.plans import syntactic_plan
    db = fresh_db()
    est = Estimator(db, db.stats)
    cache = PartitionedStageCache(default_bytes=32 << 20)
    db._stage_cache = cache
    q = fast_query(1)
    rows = {}
    for tenant in ("a", "b"):
        run = AdaptiveRun(db, q, syntactic_plan(q), est, max_hook_steps=0,
                          cache=cache.partition(tenant))
        assert run.start() is None
        rows[tenant] = [s.out_rows for s in run.result.stages]
    assert rows["a"] == rows["b"]
    apply_delta(db, DeltaBatch("title", n_append=1000, seed=9))
    assert cache.stats.invalidations == 1      # one shared O(1) counter
    ref = run_adaptive(db, q, syntactic_plan(q), est, reuse_stages=False)
    for tenant in ("a", "b"):
        run = AdaptiveRun(db, q, syntactic_plan(q), est, max_hook_steps=0,
                          cache=cache.partition(tenant))
        assert run.start() is None
        got = [s.out_rows for s in run.result.stages]
        assert got == [s.out_rows for s in ref.stages]
        assert got != rows[tenant]             # stale entries never served


def test_rate_limited_flood_cannot_starve_other_lanes(job_workload, agent):
    """A tenant flooding at t=0 occupies the lane FCFS; with QoS its token
    bucket spaces it out and fair-share tie-breaks favor the underserved
    tenant, so the other tenant's queries stop queueing behind the burst."""
    fast = fast_subset(job_workload)

    def build_stream():
        s = [Arrival(0.0, query=fast[i % 4], seed=i, tenant="flood")
             for i in range(8)]
        s += [Arrival(0.5 + i, query=fast[4 + i % 2], seed=100 + i,
                      tenant="light") for i in range(3)]
        return s

    def serve(admission):
        db = fresh_db()
        sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                              n_lanes=1, policy="edf" if admission else
                              "async", admission=admission)
        comps = sched.run(build_stream())
        return {t: [c.queue_wait for c in comps if c.tenant == t]
                for t in ("flood", "light")}

    plain = serve(None)
    reg = TenantRegistry([TenantSpec("flood", rate=0.5, burst=1),
                          TenantSpec("light", weight=4.0)])
    adm = QoSAdmission(reg, predictor=None)
    fair = serve(adm)
    assert adm.n_deferred > 0
    # under FCFS the light tenant queues behind the whole burst; under
    # QoS each light query gets a lane promptly
    assert max(fair["light"]) < max(plain["light"])
    assert max(fair["light"]) < 2.0


# ---------------------------------------------------------------- fallback
def test_qos_off_bit_identical_to_plain_async(job_workload, agent):
    """Tenancy metadata + partitioned cache with NO admission policy (and
    the FCFS base policy) must serve bit-identically to the PR-2 path."""
    def serve(**kw):
        db = fresh_db()
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=3, policy="async", **kw)
        comps, _ = svc.run(qos_stream(job_workload))
        return comps

    plain = serve()
    reg, _ = qos_setup()
    off = serve(tenants=reg)
    passthrough = serve(admission=AdmissionPolicy())

    # arrivals are copied per run: a stream that already went through a
    # QoS scheduler (deferral floors, stamped deadlines) must replay
    # through plain async untouched
    shared = qos_stream(job_workload)
    db = fresh_db()
    reg2, adm2 = qos_setup()
    QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=3,
                 policy="edf", tenants=reg2, admission=adm2).run(shared)
    assert all(a.not_before == 0.0 for a in shared)
    db = fresh_db()
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=3,
                       policy="async")
    reused, _ = svc.run(shared)

    for other in (off, passthrough, reused):
        assert [c.seq for c in plain] == [c.seq for c in other]
        assert [c.finish_t for c in plain] == [c.finish_t for c in other]
        assert [c.admit_t for c in plain] == [c.admit_t for c in other]
        assert [c.lane for c in plain] == [c.lane for c in other]
        assert [c.traj.actions for c in plain] == \
            [c.traj.actions for c in other]


# -------------------------------------------------------------- scheduling
def test_edf_reorders_by_deadline(job_workload, agent):
    fast = fast_subset(job_workload)

    def build_stream():
        return [Arrival(0.0, query=fast[i], seed=i, deadline=dl)
                for i, dl in enumerate((30.0, 10.0, 20.0))]

    def order(policy):
        db = fresh_db()
        sched = LaneScheduler(db, Estimator(db, db.stats), agent,
                              n_lanes=1, policy=policy)
        comps = sched.run(build_stream())
        return [c.seq for c in sorted(comps, key=lambda c: c.admit_t)]

    assert order("async") == [0, 1, 2]          # FCFS: stream order
    assert order("edf") == [1, 2, 0]            # earliest deadline first


def test_degraded_budget_caps_hook_steps(job_workload, agent):
    """An admission-assigned hook budget really limits act_batch
    decisions: budget 1 -> at most one action, budget 0 -> none (the
    pure syntactic/AQE plan runs)."""
    reg = TenantRegistry([TenantSpec("t", slo=200.0)])   # severity 1.5
    adm = QoSAdmission(reg, predictor=FixedPredictor(),
                       ladder=DegradationLadder())
    db = fresh_db()
    sched = LaneScheduler(db, Estimator(db, db.stats), agent, n_lanes=1,
                          policy="edf", admission=adm)
    comps = sched.run([Arrival(0.0, query=straggler_query(), seed=0,
                               tenant="t")])
    assert len(comps) == 1
    c = comps[0]
    assert c.degraded and c.hook_budget == 1
    assert len(c.traj.actions) <= 1
    # severity 2.5 -> budget 0: no hook decisions at all
    reg0 = TenantRegistry([TenantSpec("t", slo=120.0)])
    adm0 = QoSAdmission(reg0, predictor=FixedPredictor(),
                        ladder=DegradationLadder())
    db = fresh_db()
    sched = LaneScheduler(db, Estimator(db, db.stats), agent, n_lanes=1,
                          policy="edf", admission=adm0)
    comps = sched.run([Arrival(0.0, query=straggler_query(), seed=0,
                               tenant="t")])
    assert comps[0].hook_budget == 0
    assert comps[0].traj.actions == []
