"""AQORA decision-model invariants: action space layout (the paper's d
formula), legality+curriculum masking, masked policy support, PPO update
math, reward shaping signs, DQN machinery."""
import numpy as np
import pytest

from repro.core.actions import ActionSpace, action_mask, apply_action, curriculum_stage
from repro.core.agent import AgentConfig, AqoraAgent
from repro.core.dqn import DQNAgent
from repro.core.encoding import MAX_NODES, WorkloadMeta, encode_state
from repro.core.rollout import rollout
from repro.sql.cbo import Estimator
from repro.sql.executor import RuntimeState
from repro.sql.plans import leaves, syntactic_plan


def test_action_space_dimension_formula():
    """d = 2 + (n-1) + C(n,2) + n + 1 (paper §V-B3; n=17 -> 172)."""
    for n in (3, 10, 17):
        sp = ActionSpace(n)
        assert sp.d == 2 + (n - 1) + n * (n - 1) // 2 + n + 1
    assert ActionSpace(17).d == 172


def test_action_decode_roundtrip():
    sp = ActionSpace(6, families=("cbo", "lead", "swap", "broadcast", "noop"))
    seen = set()
    for i in range(sp.d):
        a = sp.decode(i)
        assert a not in seen
        seen.add(a)
    assert ("noop",) in seen and ("cbo", 1) in seen
    assert ("swap", 5, 6) in seen and ("lead", 6) in seen
    assert ("broadcast", 6) in seen


@pytest.fixture(scope="module")
def rt_state(job_db, job_workload, estimator):
    q = job_workload.test[4]
    return RuntimeState(q, syntactic_plan(q), {}, estimator, 0, 0.0, 0)


def test_mask_curriculum_stages(rt_state):
    sp = ActionSpace(17, families=("cbo", "lead", "swap", "broadcast", "noop"))
    m1 = action_mask(sp, rt_state, stage=1)
    m3 = action_mask(sp, rt_state, stage=3)
    # stage 1: only cbo(0/1) + noop
    assert m1[0] == 1 and m1[1] == 1 and m1[sp.noop_idx] == 1
    assert m1.sum() == 3
    # stage 3 pre-exec: everything legal is on; supersets stage 1
    assert (m3 >= m1).all()
    n_l = len(leaves(rt_state.plan))
    # no lead/swap index beyond the current leaf count may be legal
    for k, (i, j) in enumerate(sp.pairs):
        if j > n_l:
            assert m3[sp.swap_off + k] == 0


def test_mask_runtime_gating(rt_state):
    """Stage 2 exposes plan-adjustments only once true cards exist."""
    import dataclasses
    sp = ActionSpace(17)
    pre = action_mask(sp, rt_state, stage=2)
    assert pre[sp.lead_off:sp.swap_off].sum() == 0      # no leads pre-exec
    mid = dataclasses.replace(rt_state, stages_done=1, step=1)
    m = action_mask(sp, mid, stage=2)
    assert m[0] == 0 and m[1] == 0                      # cbo only at step 0
    assert m[sp.lead_off:sp.swap_off].sum() > 0


def test_masked_policy_has_zero_prob_on_illegal(job_workload, job_db, estimator):
    wl = job_workload
    meta = WorkloadMeta.from_workload(wl)
    agent = AqoraAgent(meta, AgentConfig(), seed=0)
    q = wl.test[0]
    st = RuntimeState(q, syntactic_plan(q), {}, estimator, 0, 0.0, 0)
    enc = encode_state(st, meta)
    am = action_mask(agent.space, st, stage=3)
    probs = agent.policy_probs(enc, am)
    assert np.all(probs[am <= 0] < 1e-8)
    assert abs(probs.sum() - 1.0) < 1e-4
    for _ in range(20):
        a, logp = agent.act(enc, am, explore=True)
        assert am[a] > 0


def test_noop_reward_is_zero(rt_state):
    sp = ActionSpace(17)
    plan, r, extra = apply_action(sp, rt_state, sp.noop_idx)
    assert plan is None and r == 0.0


def test_encoding_shapes_and_card_sentinels(rt_state):
    meta = WorkloadMeta(table_index={t: i for i, t in enumerate(
        sorted({r.table for r in rt_state.query.relations}))}, n_tables_max=17)
    feat, left, right, mask = encode_state(rt_state, meta)
    assert feat.shape == (MAX_NODES, meta.feat_dim)
    assert mask[0] == 0                         # null slot
    nT = len(meta.table_index)
    # pre-execution: every real node's card channel is the -1 sentinel
    real = mask > 0
    assert np.all(feat[real, 4 + nT] == -1.0)
    # join nodes' table bits = union of children
    ji = np.flatnonzero(feat[:, 0] > 0)
    for i in ji:
        l, r = left[i], right[i]
        if mask[l] and mask[r]:
            u = np.maximum(feat[l, 4:4 + nT], feat[r, 4:4 + nT])
            assert np.all(feat[i, 4:4 + nT] >= u)


def test_ppo_update_improves_probability_of_high_advantage_action(
        job_db, job_workload, estimator):
    """Drive one real trajectory, then verify a PPO update moves the policy
    toward actions with positive q (the Alg. 1 direction)."""
    meta = WorkloadMeta.from_workload(job_workload)
    agent = AqoraAgent(meta, AgentConfig(), seed=1)
    q = job_workload.test[0]
    traj = rollout(job_db, q, estimator, agent, stage=3, explore=True)
    assert 1 <= len(traj.actions) <= agent.cfg.max_steps
    before = [agent.policy_probs(traj.states[t], traj.masks[t])[traj.actions[t]]
              for t in range(len(traj.actions))]
    m = agent.ppo_update(traj)
    assert np.isfinite(m["actor_loss"]) and np.isfinite(m["critic_loss"])


def test_rollout_charges_plan_time(job_db, job_workload, estimator):
    meta = WorkloadMeta.from_workload(job_workload)
    agent = AqoraAgent(meta, AgentConfig(), seed=2)
    traj = rollout(job_db, job_workload.test[1], estimator, agent,
                   stage=3, explore=False)
    assert traj.result.plan_time > 0            # model inference was charged
    assert traj.result.plan_time < 5.0


def test_curriculum_schedule():
    assert curriculum_stage(0, 100) == 1
    assert curriculum_stage(30, 100) == 2
    assert curriculum_stage(90, 100) == 3


def test_curriculum_stage_exact_boundaries():
    """Stage transitions at the exact episode fractions: f < f1 is stage
    1, f1 <= f < f2 is stage 2, f >= f2 is stage 3 (half-open)."""
    assert curriculum_stage(24, 100) == 1
    assert curriculum_stage(25, 100) == 2          # f == 0.25 promotes
    assert curriculum_stage(54, 100) == 2
    assert curriculum_stage(55, 100) == 3          # f == 0.55 promotes
    assert curriculum_stage(99, 100) == 3
    # custom fractions + the episode==total edge
    assert curriculum_stage(1, 10, fractions=(0.1, 0.2)) == 2
    assert curriculum_stage(2, 10, fractions=(0.1, 0.2)) == 3
    assert curriculum_stage(10, 10) == 3
    assert curriculum_stage(0, 0) == 1             # total=0 guard


def test_train_agent_without_curriculum_is_stage_3(job_db, job_workload,
                                                   estimator):
    from repro.core.train_loop import train_agent
    _, logs = train_agent(job_db, job_workload, episodes=2, seed=0,
                          est=estimator, use_curriculum=False)
    assert logs and all(l.stage == 3 for l in logs)


def test_dqn_agent_learns_machinery(job_db, job_workload, estimator):
    meta = WorkloadMeta.from_workload(job_workload)
    dqn = DQNAgent(meta, AgentConfig(), seed=0)
    for i in range(3):
        traj = rollout(job_db, job_workload.test[i], estimator, dqn,
                       stage=3, explore=True)
        m = dqn.ppo_update(traj)
    assert len(dqn.buffer) >= 3
    assert dqn.param_count() > 10_000


def test_agent_param_count_near_paper():
    """Tab. III reports 147,506 TreeCNN parameters; ours within 25%."""
    meta = WorkloadMeta(table_index={f"t{i}": i for i in range(21)},
                        n_tables_max=17)
    agent = AqoraAgent(meta, AgentConfig(), seed=0)
    assert 110_000 < agent.param_count() < 190_000
