"""Per-architecture smoke tests (assignment requirement f) + model-level
invariants: one forward/train step on CPU with a REDUCED config of the same
family, asserting output shapes and no NaNs; decode-vs-forward consistency;
the MLA absorbed-decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.sharding import act


def _batch(cfg, key, B=2, S=24):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["memory"] = 0.01 * jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model)).astype(cfg.cdtype)
    if cfg.encoder is not None:
        batch["frames"] = 0.01 * np.asarray(jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model)), np.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: forward logits well-shaped, loss finite, one grad
    step produces finite params."""
    cfg = registry.reduced(registry.get_config(arch))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, metrics = lm.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss), arch
    assert 3.0 < float(loss) < 12.0, f"{arch}: loss {loss} implausible"

    grads = jax.grad(lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch

    memory = batch.get("memory")
    if cfg.encoder is not None:
        memory = lm.encode(params, jnp.asarray(batch["frames"]), cfg)
    logits, _, _ = lm.forward(params, batch["tokens"], cfg, memory=memory,
                              remat=False)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_decode_consistency(arch):
    """prefill+decode must reproduce the uncached forward's logits (exactly
    for dense archs; tolerance for MoE, whose capacity drops depend on the
    token count)."""
    cfg = registry.reduced(registry.get_config(arch))
    key = jax.random.PRNGKey(1)
    params = lm.init_params(key, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    memory = None
    if cfg.family == "vlm":
        memory = 0.01 * jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model)).astype(cfg.cdtype)
    if cfg.encoder is not None:
        frames = 0.01 * jax.random.normal(key, (B, cfg.encoder.n_frames, cfg.d_model))
        memory = lm.encode(params, frames, cfg)
    full, _, _ = lm.forward(params, tokens, cfg, memory=memory, remat=False)
    last, cache = lm.prefill(params, tokens[:, :S], cfg, max_len=S + 4,
                             memory=memory)
    dec, _ = lm.decode_step(params, tokens[:, S:S + 1], cache, cfg,
                            jnp.int32(S))
    tol = 0.25 if cfg.moe is not None else 1e-3
    assert float(jnp.max(jnp.abs(last - full[:, S - 1]))) < tol, arch
    assert float(jnp.max(jnp.abs(dec - full[:, S]))) < tol, arch


def test_mla_absorbed_decode_matches_naive():
    cfg = registry.reduced(registry.get_config("minicpm3-4b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab_size)
    _, cache = lm.prefill(params, tokens[:, :16], cfg, max_len=20)
    d0, _ = lm.decode_step(params, tokens[:, 16:17], cache, cfg, jnp.int32(16))
    with act.policy(act.ActivationPolicy(mla_absorb=True)):
        d1, _ = lm.decode_step(params, tokens[:, 16:17], cache, cfg, jnp.int32(16))
    assert float(jnp.max(jnp.abs(d0 - d1))) < 2e-2


def test_multi_token_decode_stream():
    """Streamed decode over 6 tokens == teacher-forced forward."""
    cfg = registry.reduced(registry.get_config("qwen3-8b"))
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 14), 0, cfg.vocab_size)
    full, _, _ = lm.forward(params, tokens, cfg, remat=False)
    _, cache = lm.prefill(params, tokens[:, :8], cfg, max_len=16)
    for t in range(8, 14):
        dec, cache = lm.decode_step(params, tokens[:, t:t + 1], cache, cfg,
                                    jnp.int32(t))
        assert float(jnp.max(jnp.abs(dec - full[:, t]))) < 1e-3, t


def test_ce_chunking_invariance():
    """Loss must not depend on the CE chunk size."""
    cfg = registry.reduced(registry.get_config("qwen1.5-4b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(4), B=2, S=32)
    l1, _ = lm.loss_fn(params, batch, cfg)
    with act.policy(act.ActivationPolicy(ce_chunk=16)):
        l2, _ = lm.loss_fn(params, batch, cfg)
    assert abs(float(l1) - float(l2)) < 1e-3


def test_attn_remat_invariance():
    """attn_remat changes memory, not math (fwd + grad)."""
    cfg = registry.reduced(registry.get_config("gemma2-27b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(5), B=1, S=16)
    f = lambda p: lm.loss_fn(p, batch, cfg)[0]
    l1, g1 = jax.value_and_grad(f)(params)
    with act.policy(act.ActivationPolicy(attn_remat=True)):
        l2, g2 = jax.value_and_grad(f)(params)
    assert abs(float(l1) - float(l2)) < 1e-4
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree_util.tree_leaves(g1),
                            jax.tree_util.tree_leaves(g2)))
    assert d < 1e-3


def test_moe_shard_map_dispatch_matches_global():
    """The §Perf shard_map dispatch must be numerically identical to the
    global dispatch on a single device (same routing, capacity, drops)."""
    from repro.launch.mesh import make_host_mesh
    cfg = registry.reduced(registry.get_config("dbrx-132b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    l0, _ = lm.loss_fn(params, {"tokens": tokens}, cfg)
    mesh = make_host_mesh()
    with mesh, act.policy(act.ActivationPolicy(moe_dispatch="shard_map",
                                               mesh=mesh)):
        l1, _ = lm.loss_fn(params, {"tokens": tokens}, cfg)
        grads = jax.grad(lambda p: lm.loss_fn(p, {"tokens": tokens}, cfg)[0])(params)
    assert abs(float(l0) - float(l1)) < 1e-3
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in jax.tree_util.tree_leaves(grads))


def test_param_counts_match_published():
    """Total/active counts land on the published model sizes."""
    expect = {
        "minicpm3-4b": (4.0, 4.2), "gemma2-27b": (26.0, 28.0),
        "qwen1.5-4b": (3.5, 4.2), "qwen3-8b": (7.5, 8.5),
        "llama-3.2-vision-90b": (85.0, 92.0), "dbrx-132b": (125.0, 135.0),
        "falcon-mamba-7b": (6.5, 7.5), "jamba-1.5-large-398b": (390.0, 405.0),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"
    # active params: scout ~17B, jamba ~94B
    assert 15 <= registry.get_config("llama4-scout-17b-a16e").active_param_count() / 1e9 <= 20
    assert 88 <= registry.get_config("jamba-1.5-large-398b").active_param_count() / 1e9 <= 100
