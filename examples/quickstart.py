"""Quickstart: the public API in 60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm

# ---- 1. pick any assigned architecture; reduced() gives a CPU-sized twin
cfg = registry.reduced(registry.get_config("qwen3-8b"))
print(f"arch: {cfg.name} ({cfg.n_layers} layers, d={cfg.d_model})")

params = lm.init_params(jax.random.PRNGKey(0), cfg)
n = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"params: {n/1e6:.2f}M")

# ---- 2. training step (loss + grads)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
loss, metrics = lm.loss_fn(params, {"tokens": tokens}, cfg)
print(f"initial loss: {float(loss):.3f} (ln V = {np.log(cfg.vocab_size):.3f})")

# ---- 3. serving: prefill a prompt, decode greedily
logits, cache = lm.prefill(params, tokens[:, :32], cfg, max_len=40)
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
out = []
for t in range(6):
    logits, cache = lm.decode_step(params, tok, cache, cfg, jnp.int32(32 + t))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out.append(int(tok[0, 0]))
print(f"greedy continuation: {out}")

# ---- 4. the paper's optimizer: run one query adaptively
from repro.sql import datagen, workloads
from repro.sql.cbo import Estimator
from repro.baselines import run_spark_default

db = datagen.make_job_like(scale=0.1, seed=0)
wl = workloads.make_workload("job", n_train=4, n_test_per_template=1)
res = run_spark_default(db, wl.test[0], Estimator(db, db.stats))
print(f"query {wl.test[0].name}: {res.latency:.2f}s simulated, "
      f"{res.total_shuffles} shuffles, {len(res.stages)} stages")
print("quickstart OK")
