"""End-to-end driver for the paper's system: train AQORA's decision model
against the staged engine on a JOB-like workload, then compare it with
Spark SQL's default configuration on held-out queries.

This is the paper-kind end-to-end run (the paper optimizes query serving,
not LM pre-training): a few hundred RL episodes on one CPU.

  PYTHONPATH=src python examples/train_aqora.py [--episodes 200]
                                                [--batch-size 8]

--batch-size > 1 drives training through the vectorized rollout engine:
B queries execute in lockstep, every stage boundary costs ONE batched
policy forward, and PPO replays the whole episode-batch in one jitted
update.

--serve additionally drives the held-out queries through the online
serving subsystem (`repro.serve`): open-loop arrivals into async lanes
with the LRU stage cache, reporting qps / p50 / p99 / cache hit rate.
--online extends --serve with the lifelong-learning loop (`repro.learn`):
serve-time trajectory harvesting, background PPO updates, and the gated
policy hot-swap.

The final agent (params + both AdamW states) is checkpointed through
`repro.checkpoint` to --ckpt-dir; --resume restores the newest valid
checkpoint and continues training from it — the same serialization path
`learn.PolicyStore` uses for online policy versions.
"""
import argparse
import logging
import time


from repro.baselines import run_spark_default
from repro.checkpoint import Checkpointer, agent_state, install_agent_state
from repro.core.agent import AgentConfig, AqoraAgent
from repro.core.encoding import WorkloadMeta
from repro.core.train_loop import evaluate, train_agent
from repro.sql import datagen, workloads
from repro.sql.cbo import Estimator

log = logging.getLogger("repro.train.example")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--batch-size", type=int, default=1,
                    help="lockstep rollout lanes (1 = serial path)")
    ap.add_argument("--serve", action="store_true",
                    help="also serve the test set through the async-lane "
                         "query service and print serving metrics")
    ap.add_argument("--online", action="store_true",
                    help="with --serve: harvest trajectories, train in the "
                         "background and hot-swap behind the probe gate")
    ap.add_argument("--lanes", type=int, default=4,
                    help="service lanes for --serve")
    ap.add_argument("--ckpt-dir", default="results/aqora_ckpt",
                    help="checkpoint directory for the trained agent")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint from --ckpt-dir "
                         "and continue training from it")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    log.info("building database + workload ...")
    db = datagen.make_job_like(scale=args.scale, seed=0)
    wl = workloads.make_workload("job", n_train=100, n_test_per_template=1)
    est = Estimator(db, db.stats)

    ckpt = Checkpointer(args.ckpt_dir)
    agent = AqoraAgent(WorkloadMeta.from_workload(wl), AgentConfig(), seed=0)
    ep0 = 0
    if args.resume:
        try:
            tree, step, extra = ckpt.restore(agent_state(agent))
            install_agent_state(agent, tree)
            ep0 = extra.get("episodes", step)
            log.info(f"resumed from checkpoint step {step} "
                  f"({ep0} episodes already trained)")
        except FileNotFoundError:
            log.info(f"no checkpoint under {args.ckpt_dir}; training fresh")

    t0 = time.time()
    log.info(f"training AQORA for {args.episodes} episodes "
          f"(curriculum: cbo-only -> +runtime leads -> full) ...")
    # a resumed agent already walked the curriculum in its first run —
    # continue at the full action space instead of re-restricting it
    agent, logs = train_agent(db, wl, episodes=args.episodes, seed=ep0,
                              est=est, log_every=50, agent=agent,
                              batch_size=args.batch_size,
                              use_curriculum=(ep0 == 0))
    log.info(f"trained in {time.time()-t0:.0f}s; "
          f"decision model: {agent.param_count()} params")
    # restore picks the NEWEST step, so this run's params must land
    # strictly past whatever is on disk (a rerun into a used dir, even a
    # shorter one, must become newest) — next_step guarantees both that
    # and that save() can't silently skip an existing step
    step = ckpt.next_step(ep0 + args.episodes)
    if not ckpt.save(step, agent_state(agent),
                     extra={"episodes": ep0 + args.episodes}):
        raise RuntimeError(f"checkpoint step {step} was not written")
    log.info(f"checkpointed agent (step {step}) -> {args.ckpt_dir}")

    rows = evaluate(db, wl.test, agent, est=est)
    aq = sum(r["total"] for r in rows)
    sp = sum(run_spark_default(db, q, est).latency for q in wl.test)
    fails_aq = sum(r["failed"] for r in rows)
    log.info(f"\nheld-out test ({len(wl.test)} queries):")
    log.info(f"  Spark default : {sp:8.1f}s")
    log.info(f"  AQORA         : {aq:8.1f}s ({(sp-aq)/sp:+.1%}) "
          f"failures={fails_aq}")
    ex = next(r for r in rows if r["actions"])
    log.info(f"  example intervention on {ex['query']}: {ex['actions']}")

    if args.serve or args.online:
        from repro.serve.driver import open_loop_stream
        from repro.serve.service import QueryService
        hooks = []
        if args.online:
            from repro.learn import make_online_loop
            harvester, learner = make_online_loop(
                agent, probe=wl.test[:4],
                store_dir=args.ckpt_dir + "/online",
                update_every=8, sample_size=8, gate_every=2, seed=0)
            hooks = [harvester, learner]
        svc = QueryService(db, agent, est=est, n_lanes=args.lanes,
                           policy="async", explore=args.online, hooks=hooks)
        stream = open_loop_stream(wl.test, rate=2.0,
                                  n_queries=3 * len(wl.test), seed=1)
        _, stats = svc.run(stream)
        log.info(f"\nonline serving ({args.lanes} async lanes, "
              f"{stats.n_completed} queries):")
        log.info(f"  qps={stats.qps:.2f} p50={stats.latency_p50:.2f}s "
              f"p99={stats.latency_p99:.2f}s fails={stats.n_failed}")
        log.info(f"  cache: {stats.cache}")
        if args.online:
            log.info(f"  learn: {learner.stats.as_dict()}")
            if learner.store is not None:
                log.info(f"  store: {learner.store.stats()}")


if __name__ == "__main__":
    main()
