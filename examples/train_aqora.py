"""End-to-end driver for the paper's system: train AQORA's decision model
against the staged engine on a JOB-like workload, then compare it with
Spark SQL's default configuration on held-out queries.

This is the paper-kind end-to-end run (the paper optimizes query serving,
not LM pre-training): a few hundred RL episodes on one CPU.

  PYTHONPATH=src python examples/train_aqora.py [--episodes 200]
                                                [--batch-size 8]

--batch-size > 1 drives training through the vectorized rollout engine:
B queries execute in lockstep, every stage boundary costs ONE batched
policy forward, and PPO replays the whole episode-batch in one jitted
update.

--serve additionally drives the held-out queries through the online
serving subsystem (`repro.serve`): open-loop arrivals into async lanes
with the LRU stage cache, reporting qps / p50 / p99 / cache hit rate.
"""
import argparse
import time

import numpy as np

from repro.baselines import run_spark_default
from repro.core.agent import AgentConfig
from repro.core.train_loop import evaluate, train_agent
from repro.sql import datagen, workloads
from repro.sql.cbo import Estimator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--batch-size", type=int, default=1,
                    help="lockstep rollout lanes (1 = serial path)")
    ap.add_argument("--serve", action="store_true",
                    help="also serve the test set through the async-lane "
                         "query service and print serving metrics")
    ap.add_argument("--lanes", type=int, default=4,
                    help="service lanes for --serve")
    args = ap.parse_args()

    print("building database + workload ...")
    db = datagen.make_job_like(scale=args.scale, seed=0)
    wl = workloads.make_workload("job", n_train=100, n_test_per_template=1)
    est = Estimator(db, db.stats)

    t0 = time.time()
    print(f"training AQORA for {args.episodes} episodes "
          f"(curriculum: cbo-only -> +runtime leads -> full) ...")
    agent, logs = train_agent(db, wl, episodes=args.episodes, seed=0,
                              cfg=AgentConfig(), est=est, log_every=50,
                              batch_size=args.batch_size)
    print(f"trained in {time.time()-t0:.0f}s; "
          f"decision model: {agent.param_count()} params")

    rows = evaluate(db, wl.test, agent, est=est)
    aq = sum(r["total"] for r in rows)
    sp = sum(run_spark_default(db, q, est).latency for q in wl.test)
    fails_aq = sum(r["failed"] for r in rows)
    print(f"\nheld-out test ({len(wl.test)} queries):")
    print(f"  Spark default : {sp:8.1f}s")
    print(f"  AQORA         : {aq:8.1f}s ({(sp-aq)/sp:+.1%}) "
          f"failures={fails_aq}")
    ex = next(r for r in rows if r["actions"])
    print(f"  example intervention on {ex['query']}: {ex['actions']}")

    if args.serve:
        from repro.serve.driver import open_loop_stream
        from repro.serve.service import QueryService
        svc = QueryService(db, agent, est=est, n_lanes=args.lanes,
                           policy="async")
        stream = open_loop_stream(wl.test, rate=2.0,
                                  n_queries=3 * len(wl.test), seed=1)
        _, stats = svc.run(stream)
        print(f"\nonline serving ({args.lanes} async lanes, "
              f"{stats.n_completed} queries):")
        print(f"  qps={stats.qps:.2f} p50={stats.latency_p50:.2f}s "
              f"p99={stats.latency_p99:.2f}s fails={stats.n_failed}")
        print(f"  cache: {stats.cache}")


if __name__ == "__main__":
    main()
