"""Batched serving: prefill + lockstep decode with KV/SSM caches, on the
attention-free falcon-mamba family (O(1) decode state).

  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.configs import registry
from repro.launch.serve import BatchedServer


def main():
    cfg = registry.reduced(registry.get_config("falcon-mamba-7b"))
    server = BatchedServer(cfg, max_batch=4)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size, (4, 48)).astype(np.int32)
    out, stats = server.generate(prompts, 24)
    print(f"prefill: {stats['prefill_s']:.2f}s  decode: {stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.0f} tok/s on 1 CPU core)")
    print(f"generated: {out[0].tolist()}")


if __name__ == "__main__":
    main()
