"""Plane B demo: AQORA's loop on distributed execution layouts.

The re-optimizer walks one-knob modifications of a training cell's layout
(attention sharding axis, remat policy, CE chunking, int8 grad reduction),
using the analytic napkin-math predictor as its fast environment — each
hypothesis is printed exactly as §Perf logs it. Pass --real to validate the
chosen layout with an actual 256-device lowering (minutes on this CPU).

  PYTHONPATH=src python examples/adaptive_layout.py [--real]
"""
import argparse

from repro.adapt.knobs import BASELINE
from repro.adapt.search import predict_delta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true")
    args = ap.parse_args()

    # measured baseline terms of qwen3-8b x train_4k (results/dryrun)
    cur = {"compute": 1.388, "memory": 11.308, "collective": 8.708,
           "bound": 11.308, "bottleneck": "memory"}
    layout = BASELINE
    print(f"baseline {layout.name()}: {cur}")
    for it in range(4):
        cands = []
        for nb in layout.neighbors("train"):
            txt, pred = predict_delta(cur, nb, layout, "train")
            terms = {k: cur[k] * pred[k] for k in ("compute", "memory", "collective")}
            cands.append((max(terms.values()), nb, txt, terms))
        cands.sort(key=lambda c: c[0])
        bound, nb, txt, terms = cands[0]
        if bound >= cur["bound"]:
            print("no flip predicted to improve the bound; stopping")
            break
        print(f"\niter {it}: hypothesis — {txt}")
        print(f"  flip to {nb.name()}: predicted bound "
              f"{cur['bound']:.2f}s -> {bound:.2f}s")
        layout = nb
        cur = {**terms, "bound": bound,
               "bottleneck": max(terms, key=terms.get)}
    print(f"\nchosen layout: {layout.name()}")
    if args.real:
        from repro.adapt.search import LayoutReoptimizer
        opt = LayoutReoptimizer("qwen3-8b", "train_4k")
        rec = opt.evaluate(layout)
        print("measured:", rec["roofline"])


if __name__ == "__main__":
    main()
