"""Train a ~25M-parameter qwen-family model for a few hundred steps with
the full substrate: deterministic prefetching pipeline, AdamW + cosine
schedule, async step-atomic checkpoints (kill it mid-run and rerun with
--restore to watch it resume).

(The assignment's "~100M for a few hundred steps" end-to-end training run
is sized down ~4x for this 1-core CPU container; on a real pod, drop
--smoke and point launch.train at the production mesh.)

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()
    _, losses = train("qwen1.5-4b", smoke=True, steps=args.steps,
                      global_batch=4, seq_len=256,
                      ckpt_dir="/tmp/repro_lm_ckpt", ckpt_every=50,
                      restore=args.restore, grad_compress=args.grad_compress,
                      log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
